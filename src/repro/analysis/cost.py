"""Loop-aware cost engine over the HLO IR: FLOPs / bytes / collective
accounting with trip-count multipliers.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE
(verified in this container), which under-reports scanned models by a
factor of n_layers. ``analyze_hlo`` walks the parsed IR
(``analysis/hlo_ir.py``) and multiplies costs through the (possibly
nested) loop structure.

Outputs per program:
  flops            dot + convolution FLOPs, trip-count weighted
  collectives      per-op-kind wire bytes (ring-model factors), dtypes
  memory_bytes     ~HBM traffic: sum of materialized buffer sizes x2
                   (write + read) + parameter bytes (approximation,
                   documented in EXPERIMENTS.md §Roofline)

The CPU backend promotes bf16 collectives to f32 in HLO; the
``_bf16_roundtrip`` logic corrects the reported wire dtype back to the
semantic one (``bf16*``) so the numbers predict a TPU execution.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List

from repro.analysis.hlo_ir import (
    COLLECTIVES,
    Op,
    _op_defs,
    compute_multipliers,
    parse_computations,
    type_bytes,
    type_shape,
)

# Ops counted as HBM-materializing for the memory-traffic model. The
# CPU backend fuses far less than TPU, so raw elementwise/convert/
# broadcast/transpose ops in CPU HLO are *excluded* — on TPU they fuse
# into their consumers. What remains (matmuls, fusions, gathers,
# reductions, copies, collectives, scan-stack slice updates) is the
# traffic a TPU execution would actually see. Documented approximation
# (EXPERIMENTS.md §Roofline).
# (iota/rng excluded: XLA:TPU generates them in-register / fuses them;
# the CPU backend materializes them — a backend artifact.)
MATERIALIZING = {
    "dot", "convolution", "fusion", "copy", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "reduce", "reduce-window",
    "sort", "cholesky", "triangular-solve", "pad", "concatenate",
    "select-and-scatter",
} | set(COLLECTIVES)


@dataclasses.dataclass
class Analysis:
    flops: float
    dot_flops: float
    conv_flops: float
    memory_bytes: float
    parameter_bytes: float
    collective_bytes: Dict[str, float]  # opcode -> wire bytes (per device)
    collective_dtypes: Dict[str, Dict[str, float]]  # opcode -> dtype -> bytes
    collective_count: int
    trip_counts: Dict[str, int]
    op_histogram: Dict[str, int]
    top_memory_ops: List[tuple] = dataclasses.field(default_factory=list)
    top_collective_ops: List[tuple] = dataclasses.field(
        default_factory=list)
    # opcode -> trip-count-weighted executions per step (a collective
    # inside a scanned layer counts n_layers times) — what the bucketing
    # fusion claim (DESIGN.md §6) is verified against
    collective_exec_counts: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    # opcode -> largest single-execution wire bytes — what the ZeRO
    # "the full-gradient all-reduce is gone" claim (DESIGN.md §9) is
    # verified against (a metric pmean stays tiny; a gradient bucket
    # does not)
    collective_max_exec_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _dot_flops(op: Op, defs: Dict[str, Op]) -> float:
    _, out_dims = type_shape(op.result)
    out_elems = math.prod(out_dims) if out_dims else 1
    lhs = defs.get(op.operands[0]) if op.operands else None
    contract = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    if m and lhs is not None:
        _, lhs_dims = type_shape(lhs.result)
        for idx in m.group(1).split(","):
            if idx != "" and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


def _conv_flops(op: Op, defs: Dict[str, Op]) -> float:
    _, out_dims = type_shape(op.result)
    out_elems = math.prod(out_dims) if out_dims else 1
    rhs = defs.get(op.operands[1]) if len(op.operands) > 1 else None
    if rhs is None:
        return 0.0
    _, k_dims = type_shape(rhs.result)
    m = re.search(r"dim_labels=\S+?_(\w+?)->", op.attrs)
    kernel_mult = 1
    if m and k_dims:
        labels = m.group(1)
        for ch, d in zip(labels, k_dims):
            if ch != "o":  # spatial digits and 'i' contribute; 'o' doesn't
                kernel_mult *= d
    else:
        kernel_mult = math.prod(k_dims[:-1]) if k_dims else 1
    return 2.0 * out_elems * kernel_mult


def _group_size(op: Op, total_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", op.attrs)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def _wire_bytes(op: Op, defs: Dict[str, Op], k: int) -> float:
    """Ring-model per-device wire bytes for one collective execution."""
    if k <= 1:
        return 0.0
    frac = (k - 1) / k
    out_b = type_bytes(op.result)
    in_b = sum(type_bytes(defs[o].result) for o in op.operands if o in defs)
    if op.opcode == "all-reduce":
        return 2.0 * in_b * frac
    if op.opcode == "all-gather":
        return out_b * frac
    if op.opcode == "reduce-scatter":
        return in_b * frac
    if op.opcode == "all-to-all":
        return in_b * frac
    if op.opcode in ("collective-permute", "collective-broadcast"):
        return max(in_b, out_b)
    return in_b


def analyze_hlo(text: str, total_devices: int = 1) -> Analysis:
    comps = parse_computations(text)
    comps.pop("__entry__", None)
    mult, trips = compute_multipliers(comps)

    flops = dot_flops = conv_flops = 0.0
    mem = 0.0
    param_bytes = 0.0
    coll_bytes: Dict[str, float] = defaultdict(float)
    coll_dtypes: Dict[str, Dict[str, float]] = defaultdict(
        lambda: defaultdict(float))
    coll_count = 0
    coll_execs: Dict[str, float] = defaultdict(float)
    coll_max: Dict[str, float] = defaultdict(float)
    histogram: Dict[str, int] = defaultdict(int)
    top_mem: List[tuple] = []
    top_coll: List[tuple] = []

    # computations that are fusion bodies: their internals don't
    # materialize — only the fusion op's output does.
    fusion_bodies = set()
    fusion_target = {}
    for ops in comps.values():
        for op in ops:
            if op.opcode == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                if m:
                    fusion_bodies.add(m.group(1))
                    fusion_target[op.name] = m.group(1)

    # pure dtype-cast fusions (no layout movement): CPU artifacts — the
    # TPU MXU consumes bf16 directly and these don't exist there.
    CAST_ONLY = {"parameter", "convert", "bitcast", "get-tuple-element",
                 "tuple"}
    # + layout movement: still real traffic, but at the semantic dtype.
    # slice/concatenate cover the bucketed gradient path (DESIGN.md §6),
    # whose bucket is a slice of a concatenated bf16 stream.
    PASSTHROUGH = CAST_ONLY | {"copy", "transpose", "reshape", "slice",
                               "concatenate"}

    def _convert_only(cname: str) -> bool:
        return all(o.opcode in CAST_ONLY for o in comps.get(cname, []))

    def _body_mentions_bf16(cname: str) -> bool:
        return any(type_shape(o.result)[0] == "bf16"
                   for o in comps.get(cname, []))

    def _bf16_roundtrip(name: str, defs: Dict[str, Op],
                        hops: int = 5) -> bool:
        """True if the (f32) value named ``name`` is a converted bf16
        value — semantically 2 bytes/element on TPU. Follows copy/
        bitcast/transpose/convert-only-fusion chains."""
        while hops > 0:
            hops -= 1
            d = defs.get(name)
            if d is None:
                return False
            if type_shape(d.result)[0] == "bf16":
                return True
            if d.opcode == "convert":
                src = defs.get(d.operands[0]) if d.operands else None
                if src and type_shape(src.result)[0] == "bf16":
                    return True
                name = d.operands[0] if d.operands else None
                continue
            if d.opcode == "fusion" and d.name in fusion_target:
                fops = comps.get(fusion_target[d.name], [])
                # CPU promotes bf16 reductions to f32 by a convert that
                # gets fused into the producer: a fusion whose ROOT
                # converts a bf16 value is a bf16 round-trip regardless
                # of what else the fusion computes (the bucketed
                # gradient pack hits this).
                froot = next((o for o in fops if o.root), None)
                if froot is not None and froot.opcode == "convert" \
                        and froot.operands:
                    fdefs = _op_defs(fops)
                    src = fdefs.get(froot.operands[0])
                    if src is not None and \
                            type_shape(src.result)[0] == "bf16":
                        return True
                if all(o.opcode in PASSTHROUGH for o in fops):
                    if _body_mentions_bf16(fusion_target[d.name]):
                        return True
                    name = d.operands[0] if d.operands else None
                    continue
            if d.opcode == "call":
                # outlined computation (XLA outlines the big gradient
                # pack): the value is whatever the callee's root is
                cm = re.search(r"to_apply=%?([\w.\-]+)", d.attrs)
                if cm and cm.group(1) in comps:
                    sub = comps[cm.group(1)]
                    sroot = next((o for o in sub if o.root), None)
                    if sroot is not None:
                        return _bf16_roundtrip(sroot.name, _op_defs(sub),
                                               hops)
                return False
            if d.opcode in ("copy", "bitcast", "transpose", "reshape",
                            "all-reduce", "reduce-scatter", "all-gather",
                            "slice", "dynamic-slice", "concatenate"):
                name = d.operands[0] if d.operands else None
                continue
            return False
        return False

    def materialized_bytes(op: Op, defs: Dict[str, Op]) -> float:
        """HBM write bytes for one op execution. dynamic-update-slice is
        in-place in XLA: traffic = the updated slice, not the full array
        (this is what makes scan stacks cheap per iteration)."""
        if op.opcode == "dynamic-update-slice":
            upd = defs.get(op.operands[1]) if len(op.operands) > 1 else None
            return type_bytes(upd.result) if upd else type_bytes(op.result)
        if op.opcode == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
            if m and m.group(1) in comps:
                fops = comps[m.group(1)]
                fbytes = type_bytes(op.result)
                # in-place scan-stack update fused behind (bit)casts:
                # count the update slice, not the whole stack buffer
                for fo in fops:
                    if fo.opcode == "dynamic-update-slice" and \
                            type_bytes(fo.result) >= 0.5 * fbytes:
                        fdefs = _op_defs(fops)
                        upd = (fdefs.get(fo.operands[1])
                               if len(fo.operands) > 1 else None)
                        if upd is not None:
                            return type_bytes(upd.result)
        return type_bytes(op.result)

    for cname, ops in comps.items():
        m_c = mult.get(cname, 0.0)
        if m_c == 0.0:
            continue
        in_fusion = cname in fusion_bodies
        defs = _op_defs(ops)
        for op in ops:
            histogram[op.opcode] += 1
            if op.opcode == "dot":
                f = _dot_flops(op, defs) * m_c
                dot_flops += f
                flops += f
            elif op.opcode == "convolution":
                f = _conv_flops(op, defs) * m_c
                conv_flops += f
                flops += f
            elif op.opcode in COLLECTIVES or (
                    op.opcode.endswith("-start") and
                    op.opcode[:-6] in COLLECTIVES):
                base = op.opcode[:-6] if op.opcode.endswith("-start") \
                    else op.opcode
                k = _group_size(op, total_devices)
                wb = _wire_bytes(op, defs, k) * m_c
                dtype, _ = type_shape(op.result)
                # semantic-dtype correction, per tuple element: each
                # operand that is a bf16->f32 round-trip runs in bf16 on
                # TPU. Factor = weighted by operand sizes.
                if dtype == "f32" or op.result.startswith("("):
                    tot = corr = 0.0
                    for o in op.operands:
                        d = defs.get(o)
                        if d is None:
                            continue
                        ob = type_bytes(d.result)
                        tot += ob
                        if type_shape(d.result)[0] == "f32" and \
                                _bf16_roundtrip(o, defs):
                            corr += ob / 2
                    if tot > 0 and corr > 0:
                        wb *= (tot - corr) / tot
                        dtype = "bf16*" if corr >= tot / 2 else "mixed*"
                coll_bytes[base] += wb
                coll_dtypes[base][dtype] += wb
                coll_count += 1
                coll_execs[base] += m_c
                coll_max[base] = max(coll_max[base],
                                     wb / m_c if m_c else wb)
                top_coll.append((wb, base, k, m_c, cname[:30],
                                 op.result[:46]))
            if op.opcode in MATERIALIZING and not in_fusion:
                b = materialized_bytes(op, defs) * m_c
                if op.opcode == "fusion" and op.name in fusion_target \
                        and _convert_only(fusion_target[op.name]):
                    b = 0.0  # CPU dtype/layout artifact; fused on TPU
                elif op.opcode in ("dot", "convolution") and op.operands \
                        and all(_bf16_roundtrip(o, defs)
                                for o in op.operands[:2]):
                    b *= 0.5  # bf16 dot/conv upcast by the CPU backend
                elif op.opcode in COLLECTIVES and op.operands and \
                        type_shape(op.result)[0] == "f32" and \
                        _bf16_roundtrip(op.operands[0], defs):
                    b *= 0.5  # collective carries a bf16 value on TPU
                elif op.opcode == "fusion" and type_shape(
                        op.result)[0] == "f32" and \
                        op.name in fusion_target and \
                        _body_mentions_bf16(fusion_target[op.name]):
                    b *= 0.5  # f32 fusion of bf16-origin values (CPU
                    # upcast artifact; TPU keeps the chain in bf16)
                mem += b
                if b > 0:
                    top_mem.append((b, op.opcode, m_c, cname[:30],
                                    op.result[:42], op.name[:34]))

    # entry parameters = resident inputs (params/opt state/batch), read once
    entry = None
    for cname, ops in comps.items():
        if mult.get(cname) == 1.0 and any(
                o.opcode == "parameter" for o in ops):
            if entry is None or len(ops) > len(comps.get(entry, [])):
                entry = cname
    if entry:
        for op in comps[entry]:
            if op.opcode == "parameter":
                param_bytes += type_bytes(op.result)

    top_mem.sort(reverse=True)
    top_coll.sort(reverse=True)
    return Analysis(
        flops=flops,
        dot_flops=dot_flops,
        conv_flops=conv_flops,
        memory_bytes=2.0 * mem + param_bytes,
        parameter_bytes=param_bytes,
        collective_bytes=dict(coll_bytes),
        collective_dtypes={k: dict(v) for k, v in coll_dtypes.items()},
        collective_count=coll_count,
        trip_counts=trips,
        op_histogram=dict(histogram),
        top_memory_ops=top_mem[:40],
        top_collective_ops=top_coll[:40],
        collective_exec_counts=dict(coll_execs),
        collective_max_exec_bytes=dict(coll_max),
    )


def gradient_sync_mode(a: Analysis,
                       metric_bytes_floor: int = 1024) -> str:
    """Classify the program's gradient-sync mechanism from its
    collective mix — the check the ZeRO mode (DESIGN.md §9) is accepted
    by: ``"reduce_scatter+all_gather"`` means scatter+gather carry the
    gradient volume AND every all-reduce is metric-sized (below
    ``metric_bytes_floor`` per execution) — i.e. the full-gradient
    all-reduce is gone; ``"hierarchical"`` means scatter+gather carry it
    AND a substantial (but shard-sized, not full-gradient) all-reduce
    runs between them — the intra-axis RS -> inter-axis AR ->
    intra-axis AG pipeline (DESIGN.md §14); ``"all_reduce"`` means
    all-reduces carry it; ``"none"`` means no substantial collectives
    at all."""
    rs = a.collective_bytes.get("reduce-scatter", 0.0)
    ag = a.collective_bytes.get("all-gather", 0.0)
    ar = a.collective_bytes.get("all-reduce", 0.0)
    ar_max = a.collective_max_exec_bytes.get("all-reduce", 0.0)
    if rs > 0 and ag > 0 and ar_max < metric_bytes_floor:
        return "reduce_scatter+all_gather"
    if rs > 0 and ag > 0 and ar_max >= metric_bytes_floor:
        return "hierarchical"
    if ar >= max(rs, ag) and ar_max >= metric_bytes_floor:
        return "all_reduce"
    if max(rs, ag, ar) == 0.0:
        return "none"
    return "mixed"
