"""Optimizers: the paper's rmsprop_warmup + baselines + ZeRO sharding
(GSPMD spec constraints in zero.py, the shard_map packed-stream update
in stream.py)."""
from repro.configs.base import OptimizerConfig
from repro.optim.interface import Optimizer  # noqa: F401
from repro.optim.lars import lars
from repro.optim.rmsprop_warmup import rmsprop_warmup
from repro.optim.sgd import momentum_sgd
from repro.optim.stream import (  # noqa: F401
    StreamOptimizer,
    make_stream_optimizer,
)

_FACTORIES = {
    "rmsprop_warmup": rmsprop_warmup,
    "momentum_sgd": momentum_sgd,
    "lars": lars,
}


def make_optimizer(cfg: OptimizerConfig, steps_per_epoch: int,
                   global_batch: int, use_fused: bool = False) -> Optimizer:
    if cfg.kind not in _FACTORIES:
        raise KeyError(f"unknown optimizer {cfg.kind!r}")
    return _FACTORIES[cfg.kind](cfg, steps_per_epoch, global_batch,
                                use_fused=use_fused)
