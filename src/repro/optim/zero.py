"""ZeRO-1 optimizer-state sharding (beyond paper; required to fit 70B+
training state on v5e).

Optimizer state mirrors param shapes. Each state leaf is sharded over the
data axes on the first dim that (a) is divisible by the DP degree and
(b) is not already TP-sharded by the param spec. The train step constrains
*gradients* to the same spec before the optimizer update, which turns the
gradient all-reduce into reduce-scatter (+ a param all-gather after the
update) — halving the straggler-critical collective volume.
"""
from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


def _dp_size(mesh: Mesh, dp_axes: Sequence[str]) -> int:
    n = 1
    for a in dp_axes:
        n *= mesh.shape[a]
    return n


def zero_spec_for(shape: Tuple[int, ...], param_spec: P, mesh: Mesh,
                  dp_axes: Sequence[str]) -> P:
    # mesh axes already consumed by the param spec (e.g. FSDP's "data" on
    # the embed dim) must not be reused on another dim
    used = set()
    for e in tuple(param_spec):
        if e is None:
            continue
        for a in ((e,) if isinstance(e, str) else e):
            used.add(a)
    dp_axes = tuple(a for a in dp_axes if a in mesh.shape and a not in used)
    dp = _dp_size(mesh, dp_axes)
    if dp <= 1 or not shape:
        return param_spec
    entries = list(param_spec) + [None] * (len(shape) - len(param_spec))
    for i, (dim, e) in enumerate(zip(shape, entries)):
        if e is None and dim % dp == 0 and dim >= dp:
            entries[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
            return P(*entries)
    return param_spec  # nothing shardable; stay with the param layout


def zero_specs(params_shapes: PyTree, param_specs: PyTree, mesh: Mesh,
               dp_axes: Sequence[str]) -> PyTree:
    """Pytree of PartitionSpecs for delta/m (and grads at the boundary)."""
    return jax.tree.map(
        lambda shp, spec: zero_spec_for(tuple(shp), spec, mesh, dp_axes),
        params_shapes, param_specs,
        is_leaf=lambda x: isinstance(x, (tuple, P)) and not isinstance(
            x, P) or isinstance(x, P))


def zero_shardings(params, param_specs, mesh, dp_axes):
    shapes = jax.tree.map(lambda p: tuple(p.shape), params)
    specs = jax.tree.map(
        lambda shp, spec: zero_spec_for(shp, spec, mesh, dp_axes),
        shapes, param_specs, is_leaf=lambda x: isinstance(x, (tuple, P)))
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
