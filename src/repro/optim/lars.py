"""LARS (You et al.) — beyond-paper alternative for extreme batch sizes,
implemented for the ablation suite (the paper's Table 1 competitor [10]
used a LARS-like approach at B=16k)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.core.schedules import make_lr_schedule
from repro.optim.interface import Optimizer, tree_zeros_like_f32
from repro.optim.rmsprop_warmup import _decay_mask


def lars(cfg: OptimizerConfig, steps_per_epoch: int, global_batch: int,
         trust_coef: float = 0.001, **_) -> Optimizer:
    lr_fn = make_lr_schedule(cfg.schedule, global_batch,
                             base_lr_per_256=cfg.base_lr_per_256,
                             warmup_epochs=cfg.warmup_epochs)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "delta": tree_zeros_like_f32(params)}

    def update(params, grads, state):
        step = state["step"]
        epoch = step.astype(jnp.float32) / steps_per_epoch
        eta = lr_fn(epoch)
        mask = _decay_mask(params)

        def leaf(g, p, d, do_decay):
            g32 = g.astype(jnp.float32)
            p32 = p.astype(jnp.float32)
            if do_decay:
                g32 = g32 + cfg.weight_decay * p32
            p_norm = jnp.linalg.norm(p32)
            g_norm = jnp.linalg.norm(g32)
            trust = jnp.where(
                (p_norm > 0) & (g_norm > 0),
                trust_coef * p_norm / (g_norm + 1e-9), 1.0)
            d_new = cfg.mu1 * d - trust * g32
            return (p32 + eta * d_new).astype(p.dtype), d_new

        out = jax.tree.map(leaf, grads, params, state["delta"], mask)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_delta = jax.tree.map(lambda t: t[1], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"step": step + 1, "delta": new_delta}, {
            "lr": eta, "epoch": epoch}

    return Optimizer(init=init, update=update, state_fields=("delta",))
