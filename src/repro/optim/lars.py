"""LARS (You et al.) — beyond-paper alternative for extreme batch sizes,
implemented for the ablation suite (the paper's Table 1 competitor [10]
used a LARS-like approach at B=16k).

This per-leaf tree update is the *reference* for the packed-stream LARS
in ``optim/stream.py`` (DESIGN.md §11): both compute squared norms
through the same ``segment_sum`` primitive and the same
``trust_from_sq`` ratio, so a single-process stream step is bitwise
equal to this one (tests/test_lars_stream.py). Bias/BN leaves — the
``NO_DECAY`` set — are exempt from the trust ratio (trust = 1) exactly
as they are exempt from weight decay, per You et al.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.core.schedules import make_lr_schedule
from repro.distributed.bucketing import segment_sq_partials
from repro.optim.interface import Optimizer, tree_zeros_like_f32
from repro.optim.rmsprop_warmup import _decay_mask


def leaf_sq_norm(x: jax.Array) -> jax.Array:
    """Squared L2 norm of one leaf via the same one-segment
    ``segment_sum`` the packed stream uses for its per-segment norms
    (``distributed/bucketing.py:segment_sq_partials``). ``jnp.sum`` /
    ``jnp.linalg.norm`` lower to a different reduction fold, so sharing
    the primitive is what keeps reference and stream bitwise-equal on
    identical operands."""
    flat = x.reshape(-1)
    return segment_sq_partials(flat, jnp.zeros(flat.shape, jnp.int32), 1)[0]


def trust_from_sq(p_sq, g_sq, trust_coef, apply_trust):
    """You et al. layer-wise trust ratio from squared norms; identity
    where ``apply_trust`` is False (bias/BN leaves, the stream's
    alignment-pad segment) or either norm vanishes. Shared verbatim by
    this reference and ``optim/stream.py``'s ``trust_ratios``."""
    p_n = jnp.sqrt(p_sq)
    g_n = jnp.sqrt(g_sq)
    return jnp.where(
        apply_trust & (p_n > 0) & (g_n > 0),
        trust_coef * p_n / (g_n + 1e-9), jnp.ones_like(p_n))


def lars(cfg: OptimizerConfig, steps_per_epoch: int, global_batch: int,
         trust_coef=None, **_) -> Optimizer:
    if trust_coef is None:
        trust_coef = cfg.trust_coef
    lr_fn = make_lr_schedule(cfg.schedule, global_batch,
                             base_lr_per_256=cfg.base_lr_per_256,
                             warmup_epochs=cfg.warmup_epochs,
                             total_epochs=cfg.total_epochs,
                             poly_power=cfg.poly_power)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "delta": tree_zeros_like_f32(params)}

    def update(params, grads, state):
        step = state["step"]
        epoch = step.astype(jnp.float32) / steps_per_epoch
        eta = lr_fn(epoch)
        mask = _decay_mask(params)

        def leaf(g, p, d, do_decay):
            g32 = g.astype(jnp.float32)
            p32 = p.astype(jnp.float32)
            if do_decay:
                g32 = g32 + cfg.weight_decay * p32
                trust = trust_from_sq(leaf_sq_norm(p32), leaf_sq_norm(g32),
                                      trust_coef, True)
            else:
                # NO_DECAY (bias/BN) leaves skip the trust ratio too:
                # plain momentum, matching the stream's masked segments
                trust = jnp.float32(1.0)
            d_new = cfg.mu1 * d - trust * g32
            return (p32 + eta * d_new).astype(p.dtype), d_new

        out = jax.tree.map(leaf, grads, params, state["delta"], mask)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_delta = jax.tree.map(lambda t: t[1], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"step": step + 1, "delta": new_delta}, {
            "lr": eta, "epoch": epoch}

    return Optimizer(init=init, update=update, state_fields=("delta",))
