"""Minimal optimizer interface (no optax in this environment).

An ``Optimizer`` owns its schedule closures; ``update`` maps
(params, grads, state) -> (new_params, new_state, metrics) and is pure, so
it jits/shards like any other function. State layout:

    {"step": i32[], "delta": tree, "m": tree?, "residual": tree?}

``delta``/``m`` mirror param shapes => they inherit param shardings (or
ZeRO shardings, see zero.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], Tuple[PyTree, PyTree, Dict]]
    # which state fields exist (for checkpoint/sharding plumbing)
    state_fields: Tuple[str, ...] = ("delta",)


def tree_zeros_like_f32(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
