"""The paper's optimizer as a GradientTransformation: hybrid
RMSprop->SGD with the ELU transition schedule and slow-start LR.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.core.optimizer import HybridHyper, hybrid_update
from repro.core.schedules import alpha_sgd_schedule, make_lr_schedule
from repro.optim.interface import Optimizer, PyTree, tree_zeros_like_f32

# path components that get no weight decay (norms, biases — standard
# large-batch practice, Goyal et al.). Matched against each path
# fragment by EXACT string equality, never substring: a param literally
# named "Dense_bias_proj" contains "bias" but is a projection weight and
# must stay decayed (regression-tested in tests/test_zero.py).
NO_DECAY = ("scale", "bias", "b_if", "b_gates", "A_log", "dt_bias", "D",
            "conv_b", "bq", "bk", "bv")


def _path_fragments(path) -> Tuple[str, ...]:
    """The name of every pytree path component, handling dict keys
    (DictKey.key), attribute nodes (GetAttrKey.name — ``str(k)`` would
    yield ".bias", silently missing the exact-match exemption) and
    sequence indices alike."""
    names = []
    for k in path:
        name = getattr(k, "key", None)
        if name is None:
            name = getattr(k, "name", None)
        if name is None:
            name = getattr(k, "idx", str(k))
        names.append(name if isinstance(name, str) else str(name))
    return tuple(names)


def _decay_mask(params: PyTree) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    leaves = [not any(n in NO_DECAY for n in _path_fragments(p))
              for p, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def rmsprop_warmup(cfg: OptimizerConfig, steps_per_epoch: int,
                   global_batch: int, use_fused: bool = False) -> Optimizer:
    lr_fn = make_lr_schedule(cfg.schedule, global_batch,
                             base_lr_per_256=cfg.base_lr_per_256,
                             warmup_epochs=cfg.warmup_epochs,
                             total_epochs=cfg.total_epochs,
                             poly_power=cfg.poly_power)
    state_dtype = jnp.dtype(cfg.state_dtype)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {
            "step": jnp.zeros((), jnp.int32),
            "delta": jax.tree.map(zeros, params),
            "m": jax.tree.map(zeros, params),
        }

    def update(params, grads, state):
        step = state["step"]
        epoch = step.astype(jnp.float32) / steps_per_epoch
        eta = lr_fn(epoch)
        a_sgd = alpha_sgd_schedule(epoch, cfg.beta_center, cfg.beta_period,
                                   kind=cfg.transition)
        h = HybridHyper(eta=eta, alpha_sgd=a_sgd, mu1=cfg.mu1, mu2=cfg.mu2,
                        eps=cfg.eps, eta_rmsprop=cfg.eta_rmsprop)
        mask = _decay_mask(params)

        if use_fused:
            from repro.kernels import ops as kops

            def leaf(g, p, d, m, do_decay):
                wd = cfg.weight_decay if do_decay else 0.0
                p2, d2, m2 = kops.fused_hybrid_update(
                    g, p, d.astype(jnp.float32), m.astype(jnp.float32),
                    h, wd)
                return p2, d2.astype(state_dtype), m2.astype(state_dtype)
        else:
            def leaf(g, p, d, m, do_decay):
                wd = cfg.weight_decay if do_decay else 0.0
                p2, d2, m2 = hybrid_update(
                    g, p, d.astype(jnp.float32), m.astype(jnp.float32),
                    h, wd)
                return p2, d2.astype(state_dtype), m2.astype(state_dtype)

        out = jax.tree.map(leaf, grads, params, state["delta"], state["m"],
                           mask)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_delta = jax.tree.map(lambda t: t[1], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"step": step + 1, "delta": new_delta, "m": new_m}
        metrics = {"lr": eta, "alpha_sgd": a_sgd, "epoch": epoch}
        return new_params, new_state, metrics

    return Optimizer(init=init, update=update, state_fields=("delta", "m"))
