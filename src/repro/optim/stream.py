"""ZeRO packed-stream optimizer: the sharded update of the reduce-scatter
sync mode (DESIGN.md §9).

In the ``--zero`` shard_map DP paths every gradient bucket is
``psum_scatter``'d instead of ``psum``'d, so each worker only ever holds
its contiguous 1/N shard of the packed gradient stream. This module owns
what happens to that shard: the optimizer state (``delta``/``m``) lives
as flat arrays in the *shard layout* of the packed stream
(``distributed/bucketing.py:shard_perm``), the hybrid RMSprop-warm-up
update runs elementwise on the shard only (optionally through the fused
Pallas kernel, ``kernels/fused_update.py``), and per-element weight
decay comes from a static ``wd_stream`` built from the same
``_decay_mask`` the tree optimizer uses — which is what makes the
updated parameters bitwise-equal to the replicated tree update
(tests/test_zero.py).

It also provides the checkpoint resharding path: converters between the
tree-layout optimizer state a non-zero run saves and the shard-layout
flat arrays a ``--zero`` run saves, so either can restore the other's
checkpoints (``checkpoint/checkpointer.py:restore(transform=...)``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import OptimizerConfig
from repro.core.optimizer import HybridHyper, alpha_rmsprop
from repro.core.schedules import alpha_sgd_schedule, make_lr_schedule
from repro.distributed.bucketing import (
    BucketPlan,
    segment_sq_partials,
    shard_layout_to_stream,
    stream_to_shard_layout,
)
from repro.optim.rmsprop_warmup import _decay_mask

PyTree = Any

ZERO_STATE_FIELDS = ("delta", "m")


@dataclasses.dataclass(frozen=True)
class StreamOptimizer:
    """The packed-shard twin of ``optim.interface.Optimizer``.

    ``init(padded_total)`` builds the flat global state (zeros, so the
    shard-layout permutation is irrelevant at init); ``update_shard``
    advances one worker's contiguous shard; ``wd_stream`` bakes the
    per-element weight-decay vector for a plan-structured tree.
    """

    init: Callable[[int], PyTree]
    # rmsprop_warmup: (p, g, delta, m, step, wd) -> (p', d', m', metrics)
    # lars:           (p, g, delta, step, wd, seg, trust) -> (p', d', metrics)
    update_shard: Callable
    wd_stream: Callable  # (tree matching plan.treedef, plan) -> np.f32[padded]
    kind: str
    state_fields: Tuple[str, ...] = ZERO_STATE_FIELDS
    # stream-LARS only (None for rmsprop_warmup): per-segment [p^2,
    # (g+wd*p)^2] partial sums over a locally-held slice, and the trust
    # vector from the psum'd totals. The psum between them belongs to
    # the caller (training/step.py) — the optimizer stays collective-free
    # so the same code runs on a ZeRO shard or the full stream.
    segment_partials: Optional[Callable] = None
    trust_ratios: Optional[Callable] = None


def make_stream_optimizer(cfg: OptimizerConfig, steps_per_epoch: int,
                          global_batch: int,
                          use_fused: bool = False) -> StreamOptimizer:
    """Packed-stream optimizers: ``rmsprop_warmup`` (the same
    ``core.optimizer.hybrid_update`` formula applied to the flat shard —
    elementwise, so position in the stream cannot change any value; the
    only per-leaf input, the decay mask, rides along as ``wd_stream``)
    and ``lars`` (elementwise update plus per-segment trust norms,
    DESIGN.md §11)."""
    if cfg.kind == "lars":
        return _make_stream_lars(cfg, steps_per_epoch, global_batch,
                                 use_fused)
    if cfg.kind == "momentum_sgd":
        return _make_stream_momentum_sgd(cfg, steps_per_epoch,
                                         global_batch)
    if cfg.kind != "rmsprop_warmup":
        raise ValueError(
            f"the packed stream shards the rmsprop_warmup, momentum_sgd "
            f"and lars updates; got optimizer kind {cfg.kind!r}")
    lr_fn = make_lr_schedule(cfg.schedule, global_batch,
                             base_lr_per_256=cfg.base_lr_per_256,
                             warmup_epochs=cfg.warmup_epochs)
    state_dtype = jnp.dtype(cfg.state_dtype)

    def init(padded_total: int) -> PyTree:
        return {
            "step": jnp.zeros((), jnp.int32),
            "delta": jnp.zeros((padded_total,), state_dtype),
            "m": jnp.zeros((padded_total,), state_dtype),
        }

    def update_shard(p_shard, g_shard, delta_shard, m_shard, step,
                     wd_shard):
        """One hybrid update on the worker-owned shard. ``wd_shard`` is
        the per-element weight decay (0.0 on no-decay leaves and on the
        alignment pad, whose g=0/m=0 elements stay exactly zero)."""
        epoch = step.astype(jnp.float32) / steps_per_epoch
        eta = lr_fn(epoch)
        a_sgd = alpha_sgd_schedule(epoch, cfg.beta_center, cfg.beta_period,
                                   kind=cfg.transition)
        h = HybridHyper(eta=eta, alpha_sgd=a_sgd, mu1=cfg.mu1, mu2=cfg.mu2,
                        eps=cfg.eps, eta_rmsprop=cfg.eta_rmsprop)
        d32 = delta_shard.astype(jnp.float32)
        m32 = m_shard.astype(jnp.float32)
        if use_fused:
            from repro.kernels import ops as kops

            p_new, d_new, m_new = kops.fused_hybrid_update(
                g_shard, p_shard, d32, m32, h, wd_shard)
        else:
            g = g_shard.astype(jnp.float32) + wd_shard * \
                p_shard.astype(jnp.float32)
            m_new = h.mu2 * m32 + (1.0 - h.mu2) * jnp.square(g)
            coef = h.alpha_sgd + alpha_rmsprop(h) / (jnp.sqrt(m_new) + h.eps)
            d_new = h.mu1 * d32 - coef * g
            p_new = (p_shard.astype(jnp.float32) + h.eta * d_new
                     ).astype(p_shard.dtype)
        metrics = {"lr": eta, "alpha_sgd": a_sgd, "epoch": epoch}
        return (p_new, d_new.astype(state_dtype), m_new.astype(state_dtype),
                metrics)

    def wd_stream(tree: PyTree, plan: BucketPlan) -> np.ndarray:
        return decay_wd_stream(tree, plan, cfg.weight_decay)

    return StreamOptimizer(init=init, update_shard=update_shard,
                           wd_stream=wd_stream, kind=cfg.kind)


def _make_stream_momentum_sgd(cfg: OptimizerConfig, steps_per_epoch: int,
                              global_batch: int) -> StreamOptimizer:
    """Stream-layout momentum SGD — the Goyal baseline sharded over the
    packed stream so ``--zero`` runs it too (the audit matrix lowers
    every mode x optimizer cell). Same ``update_shard`` signature as the
    rmsprop_warmup stream — ``m`` rides along untouched (zeros) so the
    ZeRO caller's state plumbing is identical — and the math inlines
    ``core.optimizer.momentum_sgd_update`` with the decay folded in
    elementwise: ``wd_shard`` is 0.0 off the decay set, and adding
    ``0.0 * p`` is value-neutral, so the parameters match the
    replicated tree update exactly (tests/test_audit.py)."""
    lr_fn = make_lr_schedule("goyal" if cfg.schedule == "goyal" else
                             cfg.schedule, global_batch,
                             base_lr_per_256=cfg.base_lr_per_256,
                             warmup_epochs=cfg.warmup_epochs,
                             total_epochs=cfg.total_epochs,
                             poly_power=cfg.poly_power)
    state_dtype = jnp.dtype(cfg.state_dtype)

    def init(padded_total: int) -> PyTree:
        return {
            "step": jnp.zeros((), jnp.int32),
            "delta": jnp.zeros((padded_total,), state_dtype),
            "m": jnp.zeros((padded_total,), state_dtype),
        }

    def update_shard(p_shard, g_shard, delta_shard, m_shard, step,
                     wd_shard):
        epoch = step.astype(jnp.float32) / steps_per_epoch
        eta = lr_fn(epoch)
        d32 = delta_shard.astype(jnp.float32)
        g = g_shard.astype(jnp.float32) + wd_shard * \
            p_shard.astype(jnp.float32)
        d_new = cfg.mu1 * d32 - g
        p_new = (p_shard.astype(jnp.float32) + eta * d_new
                 ).astype(p_shard.dtype)
        metrics = {"lr": eta, "epoch": epoch}
        return (p_new, d_new.astype(state_dtype),
                m_shard.astype(state_dtype), metrics)

    def wd_stream(tree: PyTree, plan: BucketPlan) -> np.ndarray:
        return decay_wd_stream(tree, plan, cfg.weight_decay)

    return StreamOptimizer(init=init, update_shard=update_shard,
                           wd_stream=wd_stream, kind=cfg.kind)


def _make_stream_lars(cfg: OptimizerConfig, steps_per_epoch: int,
                      global_batch: int,
                      use_fused: bool) -> StreamOptimizer:
    """Stream-layout LARS (DESIGN.md §11). Trust ratios need per-leaf
    norms over the *whole* stream, so the update splits in three:
    ``segment_partials`` reduces whatever slice this worker holds (the
    full stream, or a ZeRO shard — a leaf may span shard boundaries) to
    per-segment squared-norm partial sums; the caller psums the (2, L+1)
    partials over the DP axes; ``trust_ratios`` turns the totals into
    the per-segment trust vector; and ``update_shard`` applies the
    trust-scaled momentum step elementwise. Identical programs on a
    shard and on the full stream — which is what keeps all four sync
    modes in lockstep (tests/test_lars_stream.py)."""
    from repro.optim.lars import trust_from_sq

    lr_fn = make_lr_schedule(cfg.schedule, global_batch,
                             base_lr_per_256=cfg.base_lr_per_256,
                             warmup_epochs=cfg.warmup_epochs,
                             total_epochs=cfg.total_epochs,
                             poly_power=cfg.poly_power)
    state_dtype = jnp.dtype(cfg.state_dtype)

    def init(padded_total: int) -> PyTree:
        return {"step": jnp.zeros((), jnp.int32),
                "delta": jnp.zeros((padded_total,), state_dtype)}

    def segment_partials(p_loc, g_loc, wd_loc, seg_loc, num_segments):
        if use_fused:
            from repro.kernels import ops as kops
            return kops.fused_segment_sq_partials(p_loc, g_loc, wd_loc,
                                                  seg_loc, num_segments)
        p32 = p_loc.astype(jnp.float32)
        g_eff = g_loc.astype(jnp.float32) + wd_loc * p32
        return jnp.stack([segment_sq_partials(p32, seg_loc, num_segments),
                          segment_sq_partials(g_eff, seg_loc,
                                              num_segments)])

    def trust_ratios(totals, trust_mask):
        """(L+1,) trust from the psum'd (2, L+1) totals; 1.0 on masked
        segments (bias/BN leaves, the alignment pad)."""
        return trust_from_sq(totals[0], totals[1], cfg.trust_coef,
                             trust_mask)

    def update_shard(p_loc, g_loc, delta_loc, step, wd_loc, seg_loc,
                     trust):
        """One trust-scaled momentum step on the locally-held slice.
        Pad elements sit in segment L with wd=0/g=0/delta=0 and stay
        exactly zero forever."""
        epoch = step.astype(jnp.float32) / steps_per_epoch
        eta = lr_fn(epoch)
        d32 = delta_loc.astype(jnp.float32)
        if use_fused:
            from repro.kernels import ops as kops
            p_new, d_new = kops.fused_lars_update(
                g_loc, p_loc, d32, wd_loc, seg_loc, trust, eta, cfg.mu1)
        else:
            p32 = p_loc.astype(jnp.float32)
            g_eff = g_loc.astype(jnp.float32) + wd_loc * p32
            d_new = cfg.mu1 * d32 - trust[seg_loc] * g_eff
            p_new = (p32 + eta * d_new).astype(p_loc.dtype)
        metrics = {"lr": eta, "epoch": epoch}
        return p_new, d_new.astype(state_dtype), metrics

    def wd_stream(tree: PyTree, plan: BucketPlan) -> np.ndarray:
        return decay_wd_stream(tree, plan, cfg.weight_decay)

    return StreamOptimizer(init=init, update_shard=update_shard,
                           wd_stream=wd_stream, kind="lars",
                           state_fields=("delta",),
                           segment_partials=segment_partials,
                           trust_ratios=trust_ratios)


def trust_mask_segments(tree: PyTree, plan: BucketPlan) -> np.ndarray:
    """bool[len(slots) + 1]: True where a stream segment participates in
    the LARS trust ratio. The exemption set is exactly the no-decay set
    (``_decay_mask``: bias/BN leaves), per You et al.; the trailing
    alignment-pad segment is always exempt."""
    mask_leaves = plan.treedef.flatten_up_to(_decay_mask(tree))
    assert len(mask_leaves) == len(plan.slots)
    return np.asarray(list(mask_leaves) + [False], bool)


def zero_padded_total(params: PyTree, compression: str,
                      bucket_bytes: int, n_workers: int) -> int:
    """Length of the flat shard-layout optimizer state for a --zero run:
    total param elements + the shard-alignment tail. One definition of
    the layout rule, shared by launch/train.py and launch/dryrun.py —
    the padded length depends only on these scalars, never on leaf
    order, so the plain and ready-order (overlap) layouts agree.
    ``params`` may be arrays or ShapeDtypeStructs."""
    from repro.core.compression import _wire, parse_compression
    from repro.distributed.bucketing import stream_layout

    wire_name, bucketed = parse_compression(compression)
    if not bucketed:
        raise ValueError(
            "--zero reduce-scatters packed buckets: use a bucketed "
            f"compression spec (got {compression!r}, e.g. "
            "'bf16+bucketed'; DESIGN.md §9)")
    wdt = _wire(wire_name)
    itemsize = (jnp.dtype(wdt).itemsize if wdt is not None
                else jnp.dtype(jnp.float32).itemsize)
    total = sum(v.size for v in jax.tree.leaves(params))
    _, _, pad = stream_layout(total, bucket_bytes, itemsize,
                              align=n_workers)
    return total + pad


def decay_wd_stream(tree: PyTree, plan: BucketPlan,
                    weight_decay: float) -> np.ndarray:
    """Static per-element weight-decay vector for the packed stream:
    ``weight_decay`` on decayed leaves, 0.0 on ``NO_DECAY`` leaves and on
    the shard-alignment pad. ``tree`` must match ``plan.treedef`` (the
    full param tree for plain plans, the ready-ordered tuple of stage
    trees for overlap plans — leaf key names, hence the mask, are
    identical either way)."""
    mask_leaves = plan.treedef.flatten_up_to(_decay_mask(tree))
    assert len(mask_leaves) == len(plan.slots)
    wd = np.zeros((plan.padded_total,), np.float32)
    for slot, decayed in zip(plan.slots, mask_leaves):
        if decayed:
            wd[slot.offset:slot.offset + slot.size] = weight_decay
    return wd


# ---------------------------------------------------------------------------
# Checkpoint resharding (zero <-> tree optimizer-state layout)
# ---------------------------------------------------------------------------
#
# A non-zero run checkpoints opt state as one array per param leaf
# ("['opt']['delta']['stem']['conv']", ...); a --zero run checkpoints one
# flat shard-layout array per field ("['opt']['delta']"). The converters
# below rewrite a loaded checkpoint's array dict from either layout into
# the other, keyed by the *original param keystrs* in plan-slot order —
# plug them into ``checkpoint.restore(transform=...)``.


def param_key_tree(params: PyTree) -> PyTree:
    """Tree of the same structure whose leaves are each param's keystr
    (e.g. "['stem']['conv']") — the suffix every opt-state checkpoint
    key carries after "['opt']['<field>']"."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    return jax.tree_util.tree_unflatten(
        treedef, [jax.tree_util.keystr(p) for p, _ in flat])


def _slot_keys(plan: BucketPlan, key_tree: PyTree):
    keys = plan.treedef.flatten_up_to(key_tree)
    assert len(keys) == len(plan.slots)
    return keys


def zero_state_to_tree_arrays(arrays: Dict[str, np.ndarray],
                              plan: BucketPlan, key_tree: PyTree,
                              n_shards: int,
                              fields: Tuple[str, ...] = ZERO_STATE_FIELDS
                              ) -> Dict[str, np.ndarray]:
    """Rewrite a --zero checkpoint's flat shard-layout opt fields into
    per-leaf tree-layout arrays (the non-zero checkpoint schema)."""
    out = dict(arrays)
    keys = _slot_keys(plan, key_tree)
    for f in fields:
        flat_key = f"['opt']['{f}']"
        if flat_key not in out:
            raise KeyError(f"checkpoint has no shard-layout field "
                           f"{flat_key!r}; is it a --zero checkpoint?")
        stream = shard_layout_to_stream(out.pop(flat_key), plan, n_shards)
        for slot, key in zip(plan.slots, keys):
            out[flat_key + key] = stream[
                slot.offset:slot.offset + slot.size].reshape(slot.shape)
    return out


def tree_arrays_to_zero_state(arrays: Dict[str, np.ndarray],
                              plan: BucketPlan, key_tree: PyTree,
                              n_shards: int,
                              fields: Tuple[str, ...] = ZERO_STATE_FIELDS
                              ) -> Dict[str, np.ndarray]:
    """Rewrite a non-zero checkpoint's per-leaf opt fields into the flat
    shard-layout arrays a --zero run restores (pad tail = zeros, exactly
    the state the padding elements hold forever)."""
    out = dict(arrays)
    keys = _slot_keys(plan, key_tree)
    for f in fields:
        flat_key = f"['opt']['{f}']"
        parts = []
        for slot, key in zip(plan.slots, keys):
            leaf_key = flat_key + key
            if leaf_key not in out:
                raise KeyError(f"checkpoint missing {leaf_key!r}; is it "
                               "a tree-layout (non-zero) checkpoint?")
            parts.append(np.asarray(out.pop(leaf_key)).reshape(-1))
        stream = np.concatenate(parts)
        if plan.pad_elems:
            stream = np.concatenate(
                [stream, np.zeros((plan.pad_elems,), stream.dtype)])
        out[flat_key] = stream_to_shard_layout(stream, plan, n_shards)
    return out


def make_zero_restore_transform(plan: BucketPlan, key_tree: PyTree,
                                n_shards: int, to_zero: bool,
                                fields: Tuple[str, ...] = ZERO_STATE_FIELDS):
    """A ``checkpoint.restore(transform=...)`` hook crossing the
    zero/non-zero boundary: ``to_zero=True`` reshapes a tree-layout
    checkpoint for a --zero target, ``False`` the reverse. ``fields``
    names the flat opt-state arrays to convert — ``("delta", "m")`` for
    rmsprop_warmup, ``("delta",)`` for LARS (``optimizer.state_fields``)."""
    def transform(arrays, manifest):
        del manifest
        fn = (tree_arrays_to_zero_state if to_zero
              else zero_state_to_tree_arrays)
        return fn(arrays, plan, key_tree, n_shards, fields=fields)

    return transform
