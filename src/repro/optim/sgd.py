"""Momentum SGD with the Goyal et al. schedule — the paper's baseline
(what the hybrid rule reduces to at alpha_sgd = 1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.core.optimizer import HybridHyper, momentum_sgd_update
from repro.core.schedules import make_lr_schedule
from repro.optim.interface import Optimizer, tree_zeros_like_f32
from repro.optim.rmsprop_warmup import _decay_mask


def momentum_sgd(cfg: OptimizerConfig, steps_per_epoch: int,
                 global_batch: int, **_) -> Optimizer:
    lr_fn = make_lr_schedule("goyal" if cfg.schedule == "goyal" else
                             cfg.schedule, global_batch,
                             base_lr_per_256=cfg.base_lr_per_256,
                             warmup_epochs=cfg.warmup_epochs,
                             total_epochs=cfg.total_epochs,
                             poly_power=cfg.poly_power)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "delta": tree_zeros_like_f32(params)}

    def update(params, grads, state):
        step = state["step"]
        epoch = step.astype(jnp.float32) / steps_per_epoch
        eta = lr_fn(epoch)
        h = HybridHyper(eta=eta, alpha_sgd=jnp.float32(1.0), mu1=cfg.mu1)
        mask = _decay_mask(params)

        def leaf(g, p, d, do_decay):
            wd = cfg.weight_decay if do_decay else 0.0
            return momentum_sgd_update(g, p, d, h, wd)

        out = jax.tree.map(leaf, grads, params, state["delta"], mask)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_delta = jax.tree.map(lambda t: t[1], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"step": step + 1, "delta": new_delta}, {
            "lr": eta, "epoch": epoch}

    return Optimizer(init=init, update=update, state_fields=("delta",))
