"""ShapeDtypeStruct stand-ins for every model input (dry-run / AOT).

``input_specs`` builds the *batch* inputs for one (arch, shape) cell;
params / optimizer-state / cache specs are derived via ``jax.eval_shape``
so nothing is allocated (the pattern the multi-pod dry-run relies on).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.common import unbox

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                compute_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Batch inputs for the step this shape's kind lowers."""
    b = shape.global_batch
    if cfg.family == "conv":
        r = cfg.image_size
        return {"images": SDS((b, r, r, 3), compute_dtype),
                "labels": SDS((b,), jnp.int32)}

    s = shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch: Dict[str, Any] = {"tokens": SDS((b, s), jnp.int32)}
        if shape.kind == "train":
            batch["targets"] = SDS((b, s), jnp.int32)
        if cfg.vision is not None:
            batch["patches"] = SDS(
                (b, cfg.vision.num_patches, cfg.vision.patch_dim),
                compute_dtype)
        if cfg.audio is not None:
            batch["frames"] = SDS(
                (b, cfg.audio.num_frames, cfg.audio.frame_dim),
                compute_dtype)
        return batch
    if shape.kind == "decode":
        return {"tokens": SDS((b, 1), jnp.int32),
                "cache_index": SDS((), jnp.int32)}
    raise ValueError(shape.kind)


def param_specs(model, param_dtype=jnp.float32) -> Tuple[Any, Any]:
    """(ShapeDtypeStruct tree, logical-axes tree), nothing allocated."""
    boxed = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    shapes, axes = unbox(boxed)
    shapes = jax.tree.map(
        lambda s: SDS(s.shape, param_dtype
                      if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype),
        shapes)
    return shapes, axes


def cache_specs(model, batch: int, max_seq: int,
                dtype=jnp.bfloat16) -> Tuple[Any, Any]:
    """(ShapeDtypeStruct tree, logical-axes tree) for the KV/SSM cache."""
    vals = jax.eval_shape(lambda: model.cache_shape(batch, max_seq, dtype)[0])
    _, axes = model.cache_shape(1, 8, dtype)  # tiny real build: axes only
    return vals, axes
