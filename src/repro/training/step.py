"""Step builders.

Gradient-sync modes (``ParallelConfig.compression`` selects the wire
format; ``dp_mode`` selects the mechanism):
  * GSPMD (default): jit + NamedShardings; XLA inserts TP/FSDP/DP
    collectives from the logical-axis rules. Gradient "wire" compression
    is applied at the sync boundary (core/compression.py, DESIGN.md §2)
    and the dry-run verifies the resulting collective dtypes from the
    HLO.
  * shard_map DP per-leaf (paper-faithful): explicit per-worker fwd/bwd,
    explicit half-precision psum per gradient leaf (the paper's
    mechanism, DESIGN.md §2), replicated optimizer — the structure of
    ChainerMN's all-reduce data parallelism.
  * shard_map DP bucketed (``compression="bf16+bucketed"``): same step,
    but the gradient stream is packed into fixed-size contiguous buckets
    and all-reduced one bucket at a time
    (distributed/bucketing.py, DESIGN.md §6) — numerically identical to
    per-leaf, with ~leaf-count fewer collectives. Error-feedback
    residuals (``ParallelConfig.error_feedback``) thread through either
    explicit path.
  * shard_map DP overlapped (``ParallelConfig.overlap_comm``): the
    backward pass is split into per-segment VJPs (models expose
    ``loss_segments``) and each ready-order bucket's psum is launched
    the moment the bucket's last gradient leaf materializes, pipelined
    one segment deep so communication hides behind the remaining
    backward compute (DESIGN.md §8). Bitwise-identical gradients to the
    non-overlapped bucketed path.
  * shard_map DP ZeRO (``ParallelConfig.zero_dp``, ``--zero``): each
    packed bucket is **reduce-scattered** (``psum_scatter``) instead of
    all-reduced, the optimizer update runs only on the worker-owned
    contiguous shard of the stream (delta/m sharded over the DP axis,
    optim/stream.py), and the updated parameter slices are all-gathered
    back — roughly half the wire volume and 1/N the update FLOPs/state
    memory, bitwise-identical end state (DESIGN.md §9). Composes with
    both the plain bucketed path and the overlapped path (the scatter
    launches between segment VJPs behind the same barrier pipeline).
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import TrainConfig
from repro.core.compression import (
    compressed_psum,
    compressed_psum_ef,
    parse_compression,
    simulate_wire_cast,
)
from repro.distributed.sharding import activation_sharding
from repro.optim.interface import Optimizer

PyTree = Any


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def jit_train_step(step, *, donate: bool = True, **jit_kwargs):
    """jit a ``(state, batch) -> (state', metrics)`` train step with the
    state argument **donated**, so the updated params / optimizer state /
    BN model-state trees reuse the input buffers instead of allocating a
    second copy (halves the step's peak state residency — at 400B-scale
    fp32 masters that is the difference between fitting and not).

    All step builders in this module share the same state-in /
    state-out aliasing contract, so donation is always safe for them;
    ``donate=False`` keeps the inputs alive (the A/B half of the parity
    check in tests/test_donation.py, which pins that donation changes
    buffers only, never results)."""
    return jax.jit(step, donate_argnums=(0,) if donate else (),
                   **jit_kwargs)


def lower_train_hlo(step, state, batch, *, donate: bool = True,
                    **jit_kwargs):
    """Compiled-HLO text of one train step — the hook the audit
    subsystem (repro.analysis, DESIGN.md §12) uses to statically verify
    a jit site: donation/aliasing coverage, collective schedule,
    accumulation precision. ``state``/``batch`` may be real arrays or
    ``ShapeDtypeStruct`` trees (AOT — nothing is allocated).

    Returns ``(hlo_text, n_batch_params)`` where ``n_batch_params`` is
    the flattened batch leaf count — jax flattens ``(state, batch)``
    state-first, so the audit's donation pass treats every entry
    parameter except the trailing ``n_batch_params`` as donated state
    (``repro.analysis.quick_audit``)."""
    jitted = jit_train_step(step, donate=donate, **jit_kwargs)
    hlo = jitted.lower(state, batch).compile().as_text()
    return hlo, len(jax.tree.leaves(batch))


def make_train_step(model, optimizer: Optimizer, train_cfg: TrainConfig,
                    mesh: Optional[Mesh] = None,
                    rules: Optional[Dict] = None,
                    grad_constraint: Optional[Callable] = None,
                    param_shardings: Optional[PyTree] = None,
                    microbatches: int = 1):
    """GSPMD train step: state=(params, opt, model_state), batch -> state'.

    ``grad_constraint`` (optional): pins gradients to ZeRO shardings so
    the partitioner reduce-scatters instead of all-reducing.
    ``param_shardings`` (optional): pins the bf16 working copy of the
    params to the master shardings so FSDP all-gathers move bf16.
    ``microbatches`` > 1: gradient accumulation — the batch's leading dim
    is split and scanned, so peak activation memory drops by the factor
    while the gradient math is unchanged (mean of microbatch grads ==
    full-batch grad for mean losses).
    """
    # GSPMD leaves collective placement to XLA, so only the wire dtype of
    # the compression spec applies here; "+bucketed" is a shard_map-DP
    # concern (DESIGN.md §6) and is ignored by this builder.
    wire, _ = parse_compression(train_cfg.parallel.compression)

    compute_dtype = getattr(model, "compute_dtype", jnp.bfloat16)

    def train_step(state: PyTree, batch: PyTree):
        ctx = (activation_sharding(mesh, rules) if mesh is not None
               else contextlib.nullcontext())
        with ctx:
            def compute(params, mstate, mbatch):
                # cast params to the compute dtype HERE, before any FSDP
                # all-gather, so weight gathers move bf16 not fp32
                # (§Perf llama4 iteration 5). Gradients flow back to the
                # fp32 master copies through the cast.
                params = jax.tree.map(
                    lambda x: x.astype(compute_dtype)
                    if jnp.issubdtype(x.dtype, jnp.floating) else x,
                    params)
                if param_shardings is not None:
                    params = jax.lax.with_sharding_constraint(
                        params, param_shardings)
                return model.loss_fn(params, mstate, mbatch,
                                     train_cfg.label_smoothing)

            grad_fn = jax.value_and_grad(compute, has_aux=True)
            if microbatches <= 1:
                (loss, (new_mstate, metrics)), grads = grad_fn(
                    state["params"], state["model_state"], batch)
            else:
                def split(x):
                    b = x.shape[0]
                    assert b % microbatches == 0, (b, microbatches)
                    return x.reshape(microbatches, b // microbatches,
                                     *x.shape[1:])

                mb = jax.tree.map(
                    lambda x: split(x) if jnp.ndim(x) else x, batch)

                def acc_step(carry, mbatch):
                    g_acc, mstate = carry
                    (loss, (mstate, metrics)), g = grad_fn(
                        state["params"], mstate, mbatch)
                    g_acc = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32)
                        / microbatches, g_acc, g)
                    return (g_acc, mstate), metrics

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32),
                    state["params"])
                (grads, new_mstate), metrics_seq = jax.lax.scan(
                    acc_step, (g0, state["model_state"]), mb)
                # average across microbatches (equal sizes, mean losses)
                # so the logged loss is the full-batch loss — reporting
                # only the last microbatch would make the logged curve
                # depend on the accumulation factor.
                metrics = jax.tree.map(
                    lambda m: jnp.mean(m.astype(jnp.float32), axis=0),
                    metrics_seq)

            grads = simulate_wire_cast(grads, wire)
            if grad_constraint is not None:
                grads = grad_constraint(grads)
            new_params, new_opt, opt_metrics = optimizer.update(
                state["params"], grads, state["opt"])
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        if train_cfg.log_grad_norm:
            # opt-in: a full extra tree reduction per step (DESIGN.md §8)
            metrics["grad_norm"] = global_norm(grads)
        new_state = {"params": new_params, "opt": new_opt,
                     "model_state": new_mstate}
        return new_state, metrics

    return train_step


def make_eval_step(model, train_cfg: Optional[TrainConfig] = None,
                   mesh: Optional[Mesh] = None,
                   rules: Optional[Dict] = None):
    """Validation step: (params, model_state, batch) -> metrics dict.

    ``model_state`` must already be finalized (paper §2: BN statistics
    all-reduced across workers before validation — identity under GSPMD,
    ``finalize_worker_bn_stats`` under shard_map DP; DESIGN.md §7). The
    step itself is mode-agnostic: a plain jit over (possibly sharded)
    inputs, so the same compiled program serves both execution modes.
    """
    del train_cfg  # schedules don't enter the eval path

    def eval_step(params, model_state, batch) -> Dict:
        ctx = (activation_sharding(mesh, rules) if mesh is not None
               else contextlib.nullcontext())
        with ctx:
            if hasattr(model, "eval_fn"):
                return model.eval_fn(params, model_state, batch)
            loss, (_, metrics) = model.loss_fn(params, model_state, batch)
            out = {k: v for k, v in metrics.items() if jnp.ndim(v) == 0}
            out["loss"] = loss
            return out

    return eval_step


def make_prefill_step(model, mesh=None, rules=None):
    def prefill_step(params, cache, batch):
        ctx = (activation_sharding(mesh, rules) if mesh is not None
               else contextlib.nullcontext())
        with ctx:
            kw = {k: batch[k] for k in ("frames", "patches") if k in batch}
            logits, new_cache = model.prefill(params, batch["tokens"],
                                              cache, **kw)
        return logits, new_cache

    return prefill_step


def make_decode_step(model, mesh=None, rules=None):
    def decode_step(params, cache, batch):
        ctx = (activation_sharding(mesh, rules) if mesh is not None
               else contextlib.nullcontext())
        with ctx:
            logits, new_cache = model.decode_step(
                params, cache, batch["tokens"], batch["cache_index"])
        return logits, new_cache

    return decode_step


# ---------------------------------------------------------------------------
# Paper-faithful explicit-DP mode (shard_map + compressed psum)
# ---------------------------------------------------------------------------


def _pmean_metrics(metrics: Dict, dp_axes: Sequence[str]) -> Dict:
    """One collective for all scalar metrics (stack -> pmean -> split)
    instead of one tiny all-reduce per metric — keeps the step's
    collective count at n_buckets + 1 in the bucketed modes."""
    scalar_keys = sorted(k for k, v in metrics.items() if jnp.ndim(v) == 0)
    if not scalar_keys:
        return {k: jax.lax.pmean(v, dp_axes) for k, v in metrics.items()}
    stacked = jax.lax.pmean(
        jnp.stack([metrics[k].astype(jnp.float32) for k in scalar_keys]),
        dp_axes)
    return {**{k: jax.lax.pmean(v, dp_axes) for k, v in metrics.items()
               if k not in scalar_keys},
            **{k: stacked[i] for i, k in enumerate(scalar_keys)}}


def _wrap_dp_step(local_step, mesh: Mesh, dp_axes: Sequence[str],
                  use_ef: bool, opt_specs=None, aux_builder=None):
    """shard_map plumbing shared by the explicit-DP step builders:
    params/opt replicated, model_state (and EF residual) per-worker.
    ``opt_specs`` overrides the replicated default for the opt state —
    the ZeRO mode shards the stream state over the DP axis
    (DESIGN.md §9). ``aux_builder(state, batch) -> (aux, aux_specs)``,
    if given,
    appends extra input-only arguments after the EF residual — the
    packed-stream side inputs (wd/segment streams) ride in as sharded
    shard_map *inputs* instead of being baked into every rank's program
    as full-stream trace constants (DESIGN.md §11)."""
    from jax.experimental.shard_map import shard_map

    batch_spec = P(tuple(dp_axes))
    state_spec = P(tuple(dp_axes))  # per-worker last-minibatch BN / EF

    def train_step(state, batch):
        opt_spec_tree = (jax.tree.map(lambda _: P(), state["opt"])
                         if opt_specs is None else opt_specs)
        in_specs = (
            jax.tree.map(lambda _: P(), state["params"]),
            jax.tree.map(lambda _: state_spec, state["model_state"]),
            opt_spec_tree,
            # scalar batch leaves (the fused-input ``input_step`` stamp,
            # DESIGN.md §15) have no batch dim to shard: replicate them
            jax.tree.map(lambda x: batch_spec if jnp.ndim(x) else P(),
                         batch),
        )
        out_specs = (
            jax.tree.map(lambda _: P(), state["params"]),
            jax.tree.map(lambda _: state_spec, state["model_state"]),
            opt_spec_tree,
            P(),
        )
        args = (state["params"], state["model_state"], state["opt"], batch)
        if use_ef:
            ef_spec = jax.tree.map(lambda _: state_spec,
                                   state["ef_residual"])
            in_specs += (ef_spec,)
            out_specs += (ef_spec,)
            args += (state["ef_residual"],)
        if aux_builder is not None:
            aux, aux_specs = aux_builder(state, batch)
            in_specs += (aux_specs,)
            args += (aux,)
        fn = shard_map(local_step, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
        outs = fn(*args)
        new_params, new_mstate, new_opt, metrics = outs[:4]
        new_state = {"params": new_params, "opt": new_opt,
                     "model_state": new_mstate}
        if use_ef:
            new_state["ef_residual"] = outs[4]
        return new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# ZeRO reduce-scatter plumbing shared by the bucketed + overlap builders
# (DESIGN.md §9)
# ---------------------------------------------------------------------------


def _static_dp_size(dp_axes, mesh: Mesh) -> int:
    """Total DP degree as a python int (a trace constant)."""
    n = 1
    for a in dp_axes:
        n *= int(mesh.shape[a])
    return n


def _zero_checks(parallel, dp_axes, optimizer, bucketed: bool,
                 mesh: Mesh) -> int:
    """Validate a --zero step request; returns the static DP size."""
    if not bucketed:
        raise ValueError(
            "zero_dp reduce-scatters packed buckets, which requires "
            "bucketed compression (e.g. compression='bf16+bucketed', "
            f"got {parallel.compression!r}; DESIGN.md §9)")
    if not hasattr(optimizer, "update_shard"):
        raise ValueError(
            "zero_dp needs a packed-stream optimizer "
            "(optim/stream.py:make_stream_optimizer), got "
            f"{type(optimizer).__name__}")
    n = _static_dp_size(dp_axes, mesh)
    if n < 2:
        raise ValueError(f"zero_dp needs DP degree >= 2, got {n}")
    return n


def _hier_or_none(parallel, dp_axes, mesh: Mesh, bucketed: bool):
    """Build the ``Hierarchy`` for ``parallel.hier_split``, or None for
    the flat schedule. Hierarchical schedules reschedule packed buckets
    (DESIGN.md §14), so they require bucketed compression; the axis
    split itself is validated by ``make_hierarchy`` (multi-axis DP mesh,
    both stages >= 2 ranks)."""
    if parallel.hier_split is None:
        return None
    if not bucketed:
        raise ValueError(
            "hier_split reschedules packed buckets, which requires "
            "bucketed compression (e.g. compression='bf16+bucketed', "
            f"got {parallel.compression!r}; DESIGN.md §14)")
    from repro.distributed.bucketing import make_hierarchy
    return make_hierarchy(dp_axes, mesh.shape, parallel.hier_split)


def _stream_checks(parallel, optimizer, bucketed: bool) -> None:
    """Validate a non-zero packed-stream step request (stream-LARS)."""
    if not bucketed:
        raise ValueError(
            "the packed-stream optimizer updates a contiguous stream, "
            "which requires bucketed compression (e.g. "
            "compression='bf16+bucketed', got "
            f"{parallel.compression!r}; DESIGN.md §11)")
    if optimizer.kind != "lars":
        raise ValueError(
            "non-zero packed-stream updates exist for kind='lars' only "
            "(rmsprop_warmup uses the replicated tree update unless "
            f"--zero shards it); got kind={optimizer.kind!r}")


def _stream_aux(optimizer, plan, param_tree, n: int, dp_axes,
                sharded: bool):
    """Static per-element side inputs of a packed-stream update, built at
    trace level to ride in as shard_map *inputs* (the carried ROADMAP
    fix): the wd stream — and for LARS the segment-id stream and trust
    mask — are plan constants, but feeding them through ``in_specs``
    makes them one outer (shardable) array instead of a full
    padded-stream constant baked into every rank's program.

    ``sharded=True`` (ZeRO): wd/seg are converted to shard layout and
    partitioned with ``P(dp_axes)``, so worker w's block is exactly its
    shard in bucket-chunk order — matching the scattered gradient.
    ``sharded=False`` (non-zero stream-LARS): full streams, replicated.
    """
    from repro.distributed.bucketing import (
        segment_ids_stream,
        stream_to_shard_layout,
    )

    spec = P(tuple(dp_axes)) if sharded else P()

    def as_input(arr):
        return jnp.asarray(stream_to_shard_layout(arr, plan, n)
                           if sharded else arr)

    aux = {"wd": as_input(optimizer.wd_stream(param_tree, plan))}
    specs = {"wd": spec}
    if optimizer.kind == "lars":
        from repro.optim.stream import trust_mask_segments
        aux["seg"] = as_input(segment_ids_stream(plan))
        specs["seg"] = spec
        aux["trust_mask"] = jnp.asarray(
            trust_mask_segments(param_tree, plan))
        specs["trust_mask"] = P()
    return aux, specs


def _cast_divide_stream(stream, plan, n):
    """Cast a synced wire stream back to fp32 and divide by the worker
    count with exactly ``unpack()``'s ops — elementwise, so a scattered
    shard and the full stream get bitwise-equal values."""
    from repro.distributed.bucketing import _kernel_on

    acc_dtypes = {jnp.dtype(s.dtype) for s in plan.slots}
    if acc_dtypes != {jnp.dtype(jnp.float32)}:
        raise ValueError(
            "packed-stream updates need a uniform fp32 param tree; got "
            f"leaf dtypes {sorted(d.name for d in acc_dtypes)}")
    if stream.dtype != jnp.float32:
        if _kernel_on(None):
            from repro.kernels.ops import unpack_cast
            stream = unpack_cast(stream, jnp.float32)
        else:
            stream = stream.astype(jnp.float32)
    return stream / n


def _dp_linear_index(dp_axes: Sequence[str], mesh: Mesh):
    """This worker's rank in the row-major order psum_scatter/all_gather
    use over a tuple of mesh axes (pinned by bitwise parity on a (4, 2)
    dual-axis DP mesh: tests/test_zero.py::
    test_zero_bitwise_parity_two_dp_axes_8dev)."""
    w = jax.lax.axis_index(dp_axes[0])
    for a in dp_axes[1:]:
        w = w * mesh.shape[a] + jax.lax.axis_index(a)
    return w


def _zero_sharded_update(optimizer, plan, param_tree, g_shard, opt,
                         n: int, dp_axes: Sequence[str], mesh: Mesh,
                         aux, hier=None):
    """The rank-local half of the ZeRO step: cast+divide the scattered
    gradient shard exactly as ``unpack`` would (bitwise-equal elements),
    update the worker-owned param shard against the dp-sharded stream
    state, all-gather the updated slices per bucket, and unpack back to
    the plan-structured param tree.

    ``aux`` carries the per-element side inputs (``_stream_aux``,
    sharded=True): this worker's shard of the wd stream — and for LARS
    the segment-id shard plus the replicated trust mask. The LARS trust
    norms are the shard's per-segment partial sums psum'd over the DP
    axes (a leaf may span shard boundaries, DESIGN.md §11); the update
    itself stays on the worker-owned shard.

    ``hier`` swaps the per-bucket param all-gather for the two-level
    ``hierarchical_all_gather`` (bitwise-identical data movement, the
    expensive link carries 1/inner_size; DESIGN.md §14) — shard
    ownership itself is hierarchy-invariant, so nothing else changes.

    Returns ``(new_param_tree, new_opt, opt_metrics, local_sq)`` where
    ``local_sq`` is this worker's partial squared grad norm (the caller
    folds it into the stacked metrics pmean, DESIGN.md §8)."""
    import dataclasses as _dc

    from repro.distributed.bucketing import (
        hierarchical_all_gather,
        pack,
        shard_chunks,
        unpack,
    )

    g_shard = _cast_divide_stream(g_shard, plan, n)
    local_sq = jnp.sum(jnp.square(g_shard))

    chunks = shard_chunks(plan, n)
    w = _dp_linear_index(dp_axes, mesh)
    p_plan = _dc.replace(plan, wire=None,
                         stream_dtype=jnp.dtype(jnp.float32))
    p_buckets = pack(param_tree, p_plan)
    p_shard = jnp.concatenate(
        [jax.lax.dynamic_slice(b, (w * c,), (c,))
         for b, c in zip(p_buckets, chunks)])
    wd_shard = aux["wd"]

    if optimizer.kind == "lars":
        num_segments = len(plan.slots) + 1
        partials = optimizer.segment_partials(
            p_shard, g_shard, wd_shard, aux["seg"], num_segments)
        totals = jax.lax.psum(partials, tuple(dp_axes))
        trust = optimizer.trust_ratios(totals, aux["trust_mask"])
        p_new, d_new, opt_metrics = optimizer.update_shard(
            p_shard, g_shard, opt["delta"], opt["step"], wd_shard,
            aux["seg"], trust)
        new_opt = {"step": opt["step"] + 1, "delta": d_new}
    else:
        p_new, d_new, m_new, opt_metrics = optimizer.update_shard(
            p_shard, g_shard, opt["delta"], opt["m"], opt["step"],
            wd_shard)
        new_opt = {"step": opt["step"] + 1, "delta": d_new, "m": m_new}

    off, gathered = 0, []
    for c in chunks:
        piece = jax.lax.slice(p_new, (off,), (off + c,))
        if hier is not None:
            gathered.append(hierarchical_all_gather(piece, hier))
        else:
            gathered.append(jax.lax.all_gather(piece, tuple(dp_axes),
                                               tiled=True))
        off += c
    new_param_tree = unpack(gathered, p_plan)
    return new_param_tree, new_opt, opt_metrics, local_sq


def _stream_full_update(optimizer, plan, param_tree, g_stream, opt,
                        n: int, dp_axes: Sequence[str], mesh: Mesh, aux):
    """Replicated-stream LARS update for the non-zero packed paths
    (DESIGN.md §11): the update itself runs on the full synced stream on
    every worker — like the replicated tree update — but the trust norms
    come from the *identical* shard-decomposed program as the ZeRO path:
    each worker reduces only its own 1/N slice (the same chunks
    ``psum_scatter`` would hand it) and the (2, L+1) partials are
    psum'd. Same reduction tree, same fold order — which is what makes
    bucketed<->zero and overlap<->zero-overlap parameters bitwise-equal
    (tests/test_lars_stream.py).

    ``g_stream`` must already be cast+divided (``_cast_divide_stream``).
    Returns ``(new_param_tree, new_opt, opt_metrics, local_sq)``."""
    import dataclasses as _dc

    from repro.distributed.bucketing import local_shard, pack, unpack

    w = _dp_linear_index(dp_axes, mesh)
    p_plan = _dc.replace(plan, wire=None,
                         stream_dtype=jnp.dtype(jnp.float32))
    p_stream = jnp.concatenate(pack(param_tree, p_plan))

    g_loc = local_shard(g_stream, plan, n, w)
    local_sq = jnp.sum(jnp.square(g_loc))
    num_segments = len(plan.slots) + 1
    partials = optimizer.segment_partials(
        local_shard(p_stream, plan, n, w), g_loc,
        local_shard(aux["wd"], plan, n, w),
        local_shard(aux["seg"], plan, n, w), num_segments)
    totals = jax.lax.psum(partials, tuple(dp_axes))
    trust = optimizer.trust_ratios(totals, aux["trust_mask"])

    p_new, d_new, opt_metrics = optimizer.update_shard(
        p_stream, g_stream, opt["delta"], opt["step"], aux["wd"],
        aux["seg"], trust)
    new_opt = {"step": opt["step"] + 1, "delta": d_new}
    new_param_tree = unpack([p_new], p_plan)
    return new_param_tree, new_opt, opt_metrics, local_sq


def _zero_grad_norm(metrics: Dict, n: int) -> Dict:
    """Recover the global grad norm from the pmean'd per-worker partial
    sums (exact when n is a power of two — psum/n*n == psum — and a
    last-ulp metric either way; never parity-asserted)."""
    sq = metrics.pop("grad_sq_local") * n
    metrics["grad_norm"] = jnp.sqrt(sq)
    return metrics


def make_batch_input_transform(input_cfg, seed: int, model, mesh: Mesh,
                               dp_axes: Sequence[str]):
    """Per-worker fused input transform for the shard_map local steps
    (DESIGN.md §15), or None when the fused path is off.

    The returned callable runs *inside* shard_map on each worker's local
    batch slice: it pops the ``input_step`` stamp (StepStampSource),
    derives the global (B, 4) augmentation-parameter table from
    ``(seed, step)`` — bitwise-identical to the host AugmentedSource
    draw — takes this worker's row block by its DP linear rank (the same
    rank order ``P(dp_axes)`` used to place the batch rows), and applies
    the one-pass Pallas augment+normalize+cast kernel. It must hook the
    local steps rather than the model because parameter slicing needs
    ``lax.axis_index``, which only exists under shard_map (the overlap
    mode's aux_builder calls ``loss_segments`` outside it)."""
    if input_cfg is None or not input_cfg.fused:
        return None
    from repro.kernels import ops

    compute_dtype = getattr(model, "compute_dtype", jnp.bfloat16)
    n = _static_dp_size(dp_axes, mesh)
    mean = jnp.asarray(input_cfg.mean, jnp.float32)
    inv_std = 1.0 / jnp.asarray(input_cfg.std, jnp.float32)
    augment = input_cfg.augment
    max_shift = input_cfg.max_shift

    def transform(batch):
        batch = dict(batch)
        step_no = batch.pop("input_step")
        x = batch["images"]
        b_local = x.shape[0]
        if augment:
            # total must be the *global* batch: threefry draws are not
            # prefix-stable across sizes (ops.input_augment_params)
            params = ops.input_augment_params(
                seed, step_no, b_local * n, max_shift=max_shift)
            w = _dp_linear_index(dp_axes, mesh)
            mine = jax.lax.dynamic_slice(
                params, (w * b_local, 0), (b_local, 4))
            batch["images"] = ops.fused_input_train(
                x, mine, mean, inv_std, out_dtype=compute_dtype)
        else:
            batch["images"] = ops.fused_input_eval(
                x, mean, inv_std, out_dtype=compute_dtype)
        return batch

    return transform


def make_dp_shardmap_train_step(model, optimizer: Optimizer,
                                train_cfg: TrainConfig, mesh: Mesh,
                                dp_axes: Sequence[str],
                                input_transform=None):
    """Synchronous data-parallel step exactly as the paper's system:
    per-worker forward/backward, **half-precision all-reduce of
    gradients**, replicated optimizer update. Model must be pure-DP
    (params replicated), e.g. ResNet-50 or small LMs.

    ``compression="<wire>+bucketed"`` swaps the per-leaf psum for the
    bucketed subsystem (one collective per ``bucket_bytes`` of wire
    traffic, DESIGN.md §6); ``error_feedback=True`` threads rounding
    residuals through either sync path (state gains an ``ef_residual``
    entry, per-worker like the BN stats); ``zero_dp=True`` (--zero)
    swaps each bucket's all-reduce for a reduce-scatter and shards the
    optimizer update over the DP ranks (DESIGN.md §9), bitwise-equal
    end state.
    """
    from repro.distributed.bucketing import bucketed_psum, bucketed_psum_ef

    parallel = train_cfg.parallel
    wire, bucketed = parse_compression(parallel.compression)
    use_ef = parallel.error_feedback
    if use_ef and wire is None:
        raise ValueError("error_feedback requires a wire dtype "
                         f"(compression={parallel.compression!r})")
    dp_axes = tuple(dp_axes)

    if parallel.zero_dp:
        return _make_dp_zero_train_step(model, optimizer, train_cfg, mesh,
                                        dp_axes, wire, bucketed,
                                        input_transform=input_transform)
    if hasattr(optimizer, "update_shard"):
        # non-zero packed-stream optimizer (stream-LARS): replicated
        # update over the full synced stream, shard-decomposed trust
        # norms (DESIGN.md §11)
        return _make_dp_stream_train_step(model, optimizer, train_cfg,
                                          mesh, dp_axes, wire, bucketed,
                                          input_transform=input_transform)
    hier = _hier_or_none(parallel, dp_axes, mesh, bucketed)

    def sync_grads(grads, residual):
        """One of the four (per-leaf|bucketed) x (plain|EF) sync paths.

        Returns (synced, new_residual, sq_norm). The bucketed paths get
        the squared grad norm from one pass over the packed stream
        instead of a second full-tree reduction (DESIGN.md §8)."""
        if use_ef:
            if bucketed:
                return bucketed_psum_ef(
                    grads, residual, dp_axes, wire=wire,
                    bucket_bytes=parallel.bucket_bytes, with_sq_norm=True,
                    hierarchy=hier)
            synced, new_residual = compressed_psum_ef(
                grads, residual, dp_axes, wire)
            return synced, new_residual, None
        if bucketed:
            synced, sq = bucketed_psum(grads, dp_axes, wire=wire,
                                       bucket_bytes=parallel.bucket_bytes,
                                       mean=True, with_sq_norm=True,
                                       hierarchy=hier)
            return synced, None, sq
        return compressed_psum(grads, dp_axes, wire, mean=True), None, None

    def local_step(params, mstate, opt, batch, residual=None):
        if input_transform is not None:
            batch = input_transform(batch)
        # mstate leaves carry a leading per-worker dim (1, ...) locally
        local_mstate = jax.tree.map(lambda x: x[0], mstate)
        (loss, (new_mstate, metrics)), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, local_mstate, batch,
                                         train_cfg.label_smoothing)
        # ---- the paper's technique: fp16/bf16 compressed all-reduce ----
        local_residual = (jax.tree.map(lambda x: x[0], residual)
                          if use_ef else None)
        grads, new_residual, sq_norm = sync_grads(grads, local_residual)
        metrics = _pmean_metrics(metrics, dp_axes)
        new_params, new_opt, opt_metrics = optimizer.update(
            params, grads, opt)
        metrics.update(opt_metrics)
        metrics["grad_norm"] = (jnp.sqrt(sq_norm) if sq_norm is not None
                                else global_norm(grads))
        new_mstate = jax.tree.map(lambda x: x[None], new_mstate)
        out = (new_params, new_mstate, new_opt, metrics)
        if use_ef:
            out += (jax.tree.map(lambda x: x[None], new_residual),)
        return out

    return _wrap_dp_step(local_step, mesh, dp_axes, use_ef)


def _make_dp_zero_train_step(model, optimizer, train_cfg: TrainConfig,
                             mesh: Mesh, dp_axes: Sequence[str],
                             wire, bucketed: bool, input_transform=None):
    """ZeRO variant of the plain bucketed DP step (DESIGN.md §9):
    pack -> psum_scatter per bucket -> sharded optimizer update on the
    owned stream shard -> all-gather the updated param slices -> unpack.
    Error feedback stays rank-local and full-tree, applied before
    packing exactly as in ``bucketed_psum_ef`` — which is what keeps the
    residuals (and everything downstream) bitwise-equal to the
    all-reduce path."""
    from repro.core.compression import apply_error_feedback
    from repro.distributed.bucketing import (
        hierarchical_psum_scatter,
        pack,
        plan_buckets,
    )

    parallel = train_cfg.parallel
    use_ef = parallel.error_feedback
    n = _zero_checks(parallel, dp_axes, optimizer, bucketed, mesh)
    hier = _hier_or_none(parallel, dp_axes, mesh, bucketed)

    def local_step(params, mstate, opt, batch, *extra):
        residual = extra[0] if use_ef else None
        aux = extra[-1]
        if input_transform is not None:
            batch = input_transform(batch)
        local_mstate = jax.tree.map(lambda x: x[0], mstate)
        (loss, (new_mstate, metrics)), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, local_mstate, batch,
                                         train_cfg.label_smoothing)
        if use_ef:
            local_residual = jax.tree.map(lambda x: x[0], residual)
            quant, new_residual = apply_error_feedback(
                grads, local_residual, wire)
        else:
            quant, new_residual = grads, None
        # shard-aligned plan: every bucket splits evenly across the ranks
        plan = plan_buckets(quant, parallel.bucket_bytes, wire, align=n)
        if hier is not None:
            g_shard = jnp.concatenate(
                [hierarchical_psum_scatter(b, hier)
                 for b in pack(quant, plan)])
        else:
            g_shard = jnp.concatenate(
                [jax.lax.psum_scatter(b, tuple(dp_axes),
                                      scatter_dimension=0, tiled=True)
                 for b in pack(quant, plan)])
        new_params, new_opt, opt_metrics, local_sq = _zero_sharded_update(
            optimizer, plan, params, g_shard, opt, n, dp_axes, mesh, aux,
            hier=hier)
        metrics["grad_sq_local"] = local_sq
        metrics = _zero_grad_norm(_pmean_metrics(metrics, dp_axes), n)
        metrics.update(opt_metrics)
        new_mstate = jax.tree.map(lambda x: x[None], new_mstate)
        out = (new_params, new_mstate, new_opt, metrics)
        if use_ef:
            out += (jax.tree.map(lambda x: x[None], new_residual),)
        return out

    def aux_builder(state, batch):
        plan = plan_buckets(state["params"], parallel.bucket_bytes, wire,
                            align=n)
        return _stream_aux(optimizer, plan, state["params"], n, dp_axes,
                           sharded=True)

    opt_specs = {"step": P(), **{f: P(tuple(dp_axes))
                                 for f in optimizer.state_fields}}
    return _wrap_dp_step(local_step, mesh, dp_axes, use_ef,
                         opt_specs=opt_specs, aux_builder=aux_builder)


def _make_dp_stream_train_step(model, optimizer, train_cfg: TrainConfig,
                               mesh: Mesh, dp_axes: Sequence[str],
                               wire, bucketed: bool, input_transform=None):
    """Non-zero packed-stream variant of the plain bucketed DP step
    (stream-LARS, DESIGN.md §11): pack -> psum per bucket -> replicated
    update over the full fp32 stream, with the LARS trust norms reduced
    shard-by-shard exactly as the ZeRO path reduces them — which is what
    makes this path's parameters bitwise-equal to ``--zero``'s
    (tests/test_lars_stream.py). Error feedback stays rank-local and
    full-tree, applied before packing, as in ``bucketed_psum_ef``."""
    from repro.core.compression import apply_error_feedback
    from repro.distributed.bucketing import (
        hierarchical_psum,
        pack,
        plan_buckets,
    )

    parallel = train_cfg.parallel
    use_ef = parallel.error_feedback
    _stream_checks(parallel, optimizer, bucketed)
    n = _static_dp_size(dp_axes, mesh)
    hier = _hier_or_none(parallel, dp_axes, mesh, bucketed)

    def local_step(params, mstate, opt, batch, *extra):
        residual = extra[0] if use_ef else None
        aux = extra[-1]
        if input_transform is not None:
            batch = input_transform(batch)
        local_mstate = jax.tree.map(lambda x: x[0], mstate)
        (loss, (new_mstate, metrics)), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, local_mstate, batch,
                                         train_cfg.label_smoothing)
        if use_ef:
            local_residual = jax.tree.map(lambda x: x[0], residual)
            quant, new_residual = apply_error_feedback(
                grads, local_residual, wire)
        else:
            quant, new_residual = grads, None
        # shard-aligned plan (align=n): not required for the psum itself,
        # but it gives every rank the same 1/N norm slices as the ZeRO
        # reduce-scatter would — the bitwise-parity contract above
        plan = plan_buckets(quant, parallel.bucket_bytes, wire, align=n)
        if hier is not None:
            synced = [hierarchical_psum(b, hier)
                      for b in pack(quant, plan)]
        else:
            synced = [jax.lax.psum(b, tuple(dp_axes))
                      for b in pack(quant, plan)]
        g_stream = _cast_divide_stream(jnp.concatenate(synced), plan, n)
        new_params, new_opt, opt_metrics, local_sq = _stream_full_update(
            optimizer, plan, params, g_stream, opt, n, dp_axes, mesh, aux)
        metrics["grad_sq_local"] = local_sq
        metrics = _zero_grad_norm(_pmean_metrics(metrics, dp_axes), n)
        metrics.update(opt_metrics)
        new_mstate = jax.tree.map(lambda x: x[None], new_mstate)
        out = (new_params, new_mstate, new_opt, metrics)
        if use_ef:
            out += (jax.tree.map(lambda x: x[None], new_residual),)
        return out

    def aux_builder(state, batch):
        plan = plan_buckets(state["params"], parallel.bucket_bytes, wire,
                            align=n)
        return _stream_aux(optimizer, plan, state["params"], n, dp_axes,
                           sharded=False)

    return _wrap_dp_step(local_step, mesh, dp_axes, use_ef,
                         aux_builder=aux_builder)


def make_dp_overlap_train_step(model, optimizer: Optimizer,
                               train_cfg: TrainConfig, mesh: Mesh,
                               dp_axes: Sequence[str],
                               input_transform=None):
    """Backward-overlapped bucketed DP step (DESIGN.md §8).

    Same contract and bitwise-identical numerics as
    ``make_dp_shardmap_train_step`` with ``"<wire>+bucketed"``
    compression, but the gradient all-reduces launch *during* the
    backward pass: the model's loss is split into K segments
    (``model.loss_segments``), each segment's VJP is taken independently,
    and every ready-order bucket's psum is issued the moment the
    bucket's last leaf exists. ``optimization_barrier`` pins each
    collective's completion one segment downstream of its launch, so the
    interconnect works on bucket i while the VJP of segment i-1 computes
    — the paper's "aggregate finished layers in parallel with backprop"
    (Goyal et al. §Gradient aggregation; verified from the compiled HLO
    by ``launch/hlo_analysis.py:interleave_report``).
    """
    from repro.core.compression import apply_error_feedback
    from repro.distributed.bucketing import (
        hierarchical_psum,
        hierarchical_psum_scatter,
        pack_bucket,
        plan_ready_buckets,
        unpack,
    )
    from repro.models.common import staged_forward

    parallel = train_cfg.parallel
    wire, _bucketed = parse_compression(parallel.compression)
    use_ef = parallel.error_feedback
    if use_ef and wire is None:
        raise ValueError("error_feedback requires a wire dtype "
                         f"(compression={parallel.compression!r})")
    if not hasattr(model, "loss_segments"):
        raise ValueError(
            f"{type(model).__name__} has no loss_segments(); "
            "overlap_comm needs a staged model (ResNet50 / TransformerLM,"
            " DESIGN.md §8)")
    dp_axes = tuple(dp_axes)
    use_zero = parallel.zero_dp
    use_stream = hasattr(optimizer, "update_shard")
    if use_zero:
        n_static = _zero_checks(parallel, dp_axes, optimizer, _bucketed,
                                mesh)
    elif use_stream:
        # non-zero stream-LARS rides the same shard-aligned ready plan
        _stream_checks(parallel, optimizer, _bucketed)
        n_static = _static_dp_size(dp_axes, mesh)
    else:
        n_static = 1
    hier = _hier_or_none(parallel, dp_axes, mesh, _bucketed)
    # ZeRO/stream plans shard-align for scatter/trust slicing; a
    # hierarchical plain plan aligns too, so every bucket splits over
    # the inner axis (hier.n_workers == the static DP size)
    plan_align = n_static if n_static > 1 else (
        hier.n_workers if hier is not None else 1)

    def local_step(params, mstate, opt, batch, *extra):
        residual = extra[0] if use_ef else None
        aux = extra[-1] if use_stream else None
        if input_transform is not None:
            batch = input_transform(batch)
        local_mstate = jax.tree.map(lambda x: x[0], mstate)
        staged = model.loss_segments(params, local_mstate, batch,
                                     train_cfg.label_smoothing)
        n_seg = len(staged)
        # ---- forward: per-segment VJP chain ----
        loss, vjps, auxes = staged_forward(staged)
        # ready order = reverse segment order (last segment's grads
        # materialize first); the plan is shape-only, so it is a trace
        # constant like the treedef. ZeRO shard-aligns every bucket so
        # psum_scatter splits it evenly across ranks (DESIGN.md §9).
        plan = plan_ready_buckets(list(reversed(staged.seg_params)),
                                  parallel.bucket_bytes, wire,
                                  align=plan_align)
        res_rev = None
        if use_ef:
            local_residual = jax.tree.map(lambda x: x[0], residual)
            res_rev = list(reversed(staged.split_tree(local_residual)))
        n = jax.lax.psum(1, dp_axes)
        # ---- backward: VJP segment i, launch ready buckets, require
        # completion only before segment i-2 (one-segment-deep pipeline:
        # bucket i's wire time hides behind segment i-1's compute). With
        # zero_dp the launched collective is the bucket's reduce-scatter
        # — same launch points, same barrier pipeline. ----
        ct: Any = jnp.ones_like(loss)
        synced: Dict[int, jax.Array] = {}
        pending: List[List[int]] = []  # launched ids, newest last
        pack_carry = None
        new_res_rev: List[PyTree] = []
        for ridx, i in enumerate(reversed(range(n_seg))):
            if len(pending) >= 2:
                ids = pending.pop(0)
                if ids:
                    barred = jax.lax.optimization_barrier(
                        (ct, tuple(synced[b] for b in ids)))
                    ct = barred[0]
                    for b, v in zip(ids, barred[1]):
                        synced[b] = v
            g_seg, ct = vjps[i](ct)
            if use_ef:
                g_seg, r_new = apply_error_feedback(g_seg, res_rev[ridx],
                                                    wire)
                new_res_rev.append(r_new)
            ready, pack_carry = pack_bucket(plan, ridx, g_seg, pack_carry)
            launched = []
            for b, arr in ready:
                # with a hierarchy the whole two-level schedule launches
                # here; the barrier pipeline pins only its completion,
                # exactly as for the flat collective (DESIGN.md §14)
                if use_zero:
                    synced[b] = (
                        hierarchical_psum_scatter(arr, hier)
                        if hier is not None else
                        jax.lax.psum_scatter(arr, tuple(dp_axes),
                                             scatter_dimension=0,
                                             tiled=True))
                else:
                    synced[b] = (hierarchical_psum(arr, hier)
                                 if hier is not None else
                                 jax.lax.psum(arr, dp_axes))
                launched.append(b)
            pending.append(launched)
        assert len(synced) == plan.n_buckets, (len(synced), plan.n_buckets)
        new_mstate, metrics = staged.finalize_aux(auxes)
        if use_zero:
            # scattered shards (bucket order) -> sharded update ->
            # all-gather updated param slices -> ready-ordered stage
            # trees -> merge back to the full param structure
            g_shard = jnp.concatenate(
                [synced[b] for b in range(plan.n_buckets)])
            param_rev = tuple(reversed(staged.seg_params))
            new_param_rev, new_opt, opt_metrics, local_sq = \
                _zero_sharded_update(optimizer, plan.base, param_rev,
                                     g_shard, opt, n_static, dp_axes,
                                     mesh, aux, hier=hier)
            new_params = staged.merge_grads(
                list(reversed(list(new_param_rev))))
            metrics["grad_sq_local"] = local_sq
            metrics = _zero_grad_norm(_pmean_metrics(metrics, dp_axes),
                                      n_static)
        elif use_stream:
            # non-zero stream-LARS: full all-reduced stream, replicated
            # update; trust norms shard-decomposed as in the ZeRO branch
            g_stream = _cast_divide_stream(
                jnp.concatenate([synced[b]
                                 for b in range(plan.n_buckets)]),
                plan.base, n_static)
            param_rev = tuple(reversed(staged.seg_params))
            new_param_rev, new_opt, opt_metrics, local_sq = \
                _stream_full_update(optimizer, plan.base, param_rev,
                                    g_stream, opt, n_static, dp_axes,
                                    mesh, aux)
            new_params = staged.merge_grads(
                list(reversed(list(new_param_rev))))
            metrics["grad_sq_local"] = local_sq
            metrics = _zero_grad_norm(_pmean_metrics(metrics, dp_axes),
                                      n_static)
        else:
            stage_grads_rev, sq_norm = unpack(
                [synced[b] for b in range(plan.n_buckets)], plan.base,
                denom=n, with_sq_norm=True)
            grads = staged.merge_grads(
                list(reversed(list(stage_grads_rev))))
            metrics = _pmean_metrics(metrics, dp_axes)
            new_params, new_opt, opt_metrics = optimizer.update(
                params, grads, opt)
            metrics["grad_norm"] = jnp.sqrt(sq_norm)
        metrics.update(opt_metrics)
        new_mstate = jax.tree.map(lambda x: x[None], new_mstate)
        out = (new_params, new_mstate, new_opt, metrics)
        if use_ef:
            new_residual = staged.merge_grads(
                list(reversed(new_res_rev)))
            out += (jax.tree.map(lambda x: x[None], new_residual),)
        return out

    opt_specs = ({"step": P(), **{f: P(tuple(dp_axes))
                                  for f in optimizer.state_fields}}
                 if use_zero else None)

    def aux_builder(state, batch):
        # loss_segments at trace level is compute-free (the segment
        # closures go unexecuted) — we only need seg_params for the
        # ready-order plan. Outer model_state leaves carry the leading
        # per-worker dim, hence the x[0].
        staged = model.loss_segments(
            state["params"],
            jax.tree.map(lambda x: x[0], state["model_state"]), batch,
            train_cfg.label_smoothing)
        param_rev = tuple(reversed(staged.seg_params))
        plan = plan_ready_buckets(list(param_rev), parallel.bucket_bytes,
                                  wire, align=plan_align).base
        return _stream_aux(optimizer, plan, param_rev, n_static, dp_axes,
                           sharded=use_zero)

    return _wrap_dp_step(local_step, mesh, dp_axes, use_ef,
                         opt_specs=opt_specs,
                         aux_builder=aux_builder if use_stream else None)


def replicate_model_state(state: PyTree, n_workers: int) -> PyTree:
    """Give BN stats a leading per-worker dim for the shard_map DP mode."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_workers,) + x.shape).copy(), state)


def finalize_worker_bn_stats(state: PyTree) -> PyTree:
    """Paper §2: all-reduce the per-worker last-minibatch BN statistics
    before validation (the all-reduce happens when XLA gathers the
    worker-sharded stats for the mean). Variances are combined
    moment-correctly (via E[x^2]) so the result equals the global-batch
    statistics — see ``core.batchnorm.combine_worker_bn_stats`` and
    DESIGN.md §7."""
    from repro.core.batchnorm import combine_worker_bn_stats

    return combine_worker_bn_stats(state)
