"""Training loop: jitted step + prefetching data + async checkpointing +
fault-tolerance hooks (resume, straggler deadline accounting).

The loop is deliberately thin — all heavy lifting is in the jitted step —
so at 1000+ nodes the host-side critical path is just `device_put` of the
next batch (prefetched) and dispatch.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import AsyncCheckpointer, list_checkpoints, restore
from repro.data.synthetic import Prefetcher

PyTree = Any


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    log_every: int = 10
    # straggler mitigation: if a step exceeds deadline_factor x the median
    # step time, it is logged as a straggler event; at cluster scale the
    # launcher uses this to trigger backup-step execution (DESIGN.md §5).
    deadline_factor: float = 3.0


@dataclasses.dataclass
class LoopResult:
    state: PyTree
    history: list
    straggler_events: list
    resumed_from: Optional[int]


def run_training(
    train_step: Callable,  # jitted (state, batch) -> (state, metrics)
    state: PyTree,
    data,  # has batch_at(step)
    loop_cfg: LoopConfig,
    put_batch: Optional[Callable] = None,  # host batch -> device arrays
    metadata: Optional[Dict] = None,
    state_shardings: Optional[PyTree] = None,
) -> LoopResult:
    ckpt = (AsyncCheckpointer(loop_cfg.checkpoint_dir,
                              loop_cfg.keep_checkpoints)
            if loop_cfg.checkpoint_dir else None)

    # ---- resume (fault tolerance: restart from newest valid manifest) ----
    start_step = 0
    resumed_from = None
    if ckpt and list_checkpoints(loop_cfg.checkpoint_dir):
        state, manifest = restore(loop_cfg.checkpoint_dir, target=state,
                                  shardings=state_shardings)
        start_step = manifest["step"]
        resumed_from = start_step

    prefetch = Prefetcher(data, start_step=start_step, transform=put_batch)
    history = []
    straggler_events = []
    step_times = []
    try:
        for step in range(start_step, loop_cfg.total_steps):
            t0 = time.perf_counter()  # includes data wait: that's what a
            got_step, batch = next(prefetch)  # straggling host looks like
            assert got_step == step, (got_step, step)
            state, metrics = train_step(state, batch)
            loss = metrics.get("loss")
            if loss is not None:
                loss = float(jax.device_get(loss))  # sync point
            dt = time.perf_counter() - t0
            step_times.append(dt)
            med = float(np.median(step_times[-50:]))
            if len(step_times) > 5 and dt > loop_cfg.deadline_factor * med:
                straggler_events.append({"step": step, "time": dt,
                                         "median": med})
            if step % loop_cfg.log_every == 0 or step == \
                    loop_cfg.total_steps - 1:
                history.append({"step": step, "loss": loss, "time": dt})
            if ckpt and (step + 1) % loop_cfg.checkpoint_every == 0:
                ckpt.save(step + 1, state, metadata=metadata)
        if ckpt:
            ckpt.save(loop_cfg.total_steps, state, metadata=metadata,
                      block=True)
    finally:
        prefetch.close()
        if ckpt:
            ckpt.wait()
    return LoopResult(state=state, history=history,
                      straggler_events=straggler_events,
                      resumed_from=resumed_from)
