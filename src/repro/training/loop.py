"""Training loop: epoch-aware trainer interleaving jitted train steps
with jitted validation, plus prefetching data, async checkpointing and
fault-tolerance hooks (resume, straggler deadline accounting).

The paper's headline claim is a *validation* number, and its §2 BN
technique only exists at validation time: the last-minibatch BN
statistics are all-reduced across workers right before each eval
(DESIGN.md §7). ``Trainer`` owns that interleaving for both execution
modes — GSPMD (stats already global; ``finalize_state`` is identity)
and shard_map DP (``finalize_worker_bn_stats`` merges the per-worker
statistics). It also owns per-epoch top-1/loss history, best-checkpoint
retention, and eval-state resume.

The hot loop stays deliberately thin — all heavy lifting is in the
jitted steps — so at 1000+ nodes the host-side critical path is just
`device_put` of the next batch (prefetched) and dispatch. Validation
runs only at epoch boundaries, off the steady-state path.

``run_training`` remains as the legacy step-driven API (one epoch, no
eval) layered on the same loop.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import AsyncCheckpointer, list_checkpoints, restore
from repro.checkpoint.checkpointer import BEST_DIR
from repro.data.pipeline import DataPipeline
from repro.resilience.events import EventLog
from repro.resilience.recovery import (Action, RecoveryManager,
                                       ResilienceConfig)
from repro.resilience.sentinel import SENTINEL_METRICS

PyTree = Any


@dataclasses.dataclass
class TrainerConfig:
    epochs: int = 1
    steps_per_epoch: int = 100
    # validation cadence: every N epochs (0 disables eval entirely);
    # the final epoch is always evaluated when eval is enabled.
    eval_every_epochs: int = 1
    val_batches: int = 4
    checkpoint_every: int = 50  # steps; 0 => final checkpoint only
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    keep_best: bool = True  # retain best-top-1 state outside the GC window
    log_every: int = 10
    # straggler mitigation: if a step exceeds deadline_factor x the median
    # step time, it is logged as a straggler event; at cluster scale the
    # launcher uses this to trigger backup-step execution (DESIGN.md §5).
    deadline_factor: float = 3.0
    # input pipeline (DESIGN.md §15): host producer threads, reorder-
    # buffer bound, and how many steps to stage on device ahead of
    # consumption (device staging needs put_batch)
    data_workers: int = 1
    prefetch_depth: int = 4
    device_ahead: int = 1


@dataclasses.dataclass
class TrainResult:
    state: PyTree
    history: list  # per-step train log ({"step", "loss", "time"})
    epoch_history: list  # per-eval {"epoch", "step", "top1", "loss", ...}
    straggler_events: list
    resumed_from: Optional[int]
    best: Optional[Dict]  # {"top1", "epoch", "step"} (eval enabled only)
    # resilience event records (DESIGN.md §13): skipped steps, rollbacks,
    # chaos injections, corrupt checkpoints skipped on restore
    events: list = dataclasses.field(default_factory=list)
    # input-boundedness attribution (DESIGN.md §15): total wall time,
    # total time blocked on the input pipeline, and their ratio —
    # ~0 means compute-bound, ~1 means data-starved
    input_stats: Dict = dataclasses.field(default_factory=dict)


class Trainer:
    """Epoch-driven train/eval loop (DESIGN.md §7).

    ``train_step``: jitted (state, batch) -> (state, metrics).
    ``eval_step``: jitted (params, model_state, batch) -> metrics dict
        (must contain ``top1`` for best-checkpoint tracking; see
        ``training.step.make_eval_step``).
    ``finalize_state``: model_state -> eval model_state, the paper's
        pre-validation BN all-reduce. None = identity (GSPMD, where the
        partitioner already made the statistics global); shard_map DP
        passes ``finalize_worker_bn_stats``.
    ``val_data``: held-out pipeline with ``batch_at(i)`` disjoint from
        the training split (``data.synthetic`` split contract); eval
        always replays batches ``0..val_batches-1`` so every epoch is
        scored on the same held-out set.
    """

    def __init__(self, train_step: Callable, state: PyTree, train_data,
                 cfg: TrainerConfig, *, eval_step: Optional[Callable] = None,
                 val_data=None, finalize_state: Optional[Callable] = None,
                 put_batch: Optional[Callable] = None,
                 metadata: Optional[Dict] = None,
                 state_shardings: Optional[PyTree] = None,
                 resilience: Optional[ResilienceConfig] = None,
                 chaos=None):
        if cfg.eval_every_epochs and eval_step is not None \
                and val_data is None:
            raise ValueError("eval enabled but no val_data given")
        self.train_step = train_step
        self.state = state
        self.train_data = train_data
        self.cfg = cfg
        self.eval_step = eval_step
        self.val_data = val_data
        self.finalize_state = finalize_state
        self.put_batch = put_batch
        self.metadata = dict(metadata or {})
        self.state_shardings = state_shardings
        # fault tolerance (DESIGN.md §13): with `resilience` set,
        # ``train_step`` must be the 3-arg sentinel-wrapped form
        # (resilience.sentinel.wrap_step_with_sentinel); `chaos` is a
        # resilience.chaos.ChaosEngine for deterministic fault injection
        self.resilience = resilience
        self.chaos = chaos
        self._val_batches = None  # built once: the held-out set is fixed

    # ------------------------------------------------------------- eval
    def _eval_enabled(self) -> bool:
        return (self.eval_step is not None
                and self.cfg.eval_every_epochs > 0
                and self.cfg.val_batches > 0)

    def evaluate(self, state: PyTree, epoch: int, step: int) -> Dict:
        """One validation pass over the held-out set. Applies the
        pre-validation BN finalize, then averages the jitted eval
        metrics over ``val_batches`` fixed batches."""
        mstate = state["model_state"]
        if self.finalize_state is not None:
            mstate = self.finalize_state(mstate)
        if self._val_batches is None:
            batches = [self.val_data.batch_at(i)
                       for i in range(self.cfg.val_batches)]
            if self.put_batch is not None:
                batches = [self.put_batch(b) for b in batches]
            self._val_batches = batches
        sums: Dict[str, float] = {}
        for batch in self._val_batches:
            metrics = self.eval_step(state["params"], mstate, batch)
            for k, v in metrics.items():
                sums[k] = sums.get(k, 0.0) + float(jax.device_get(v))
        rec = {k: v / self.cfg.val_batches for k, v in sums.items()}
        rec.update(epoch=epoch, step=step)
        return rec

    # -------------------------------------------------------------- run
    def _ckpt_metadata(self, eval_history: List[Dict],
                       best: Optional[Dict]) -> Dict:
        # snapshot, not reference: AsyncCheckpointer json.dumps metadata
        # on a background thread while the loop keeps appending records
        meta = dict(self.metadata)
        meta["eval_history"] = [dict(r) for r in eval_history]
        if best is not None:
            meta["best"] = dict(best)
        return meta

    def run(self) -> TrainResult:
        cfg = self.cfg
        total_steps = cfg.epochs * cfg.steps_per_epoch
        ckpt = (AsyncCheckpointer(cfg.checkpoint_dir, cfg.keep_checkpoints)
                if cfg.checkpoint_dir else None)
        # best-top-1 retention, off the hot path: snapshot on this
        # thread, serialize off-thread; keep=1 GC leaves exactly one
        # best checkpoint, outside the main rotating window
        best_ckpt = (AsyncCheckpointer(
            os.path.join(cfg.checkpoint_dir, BEST_DIR), keep=1)
            if ckpt and self._eval_enabled() and cfg.keep_best else None)

        # ---- resilience plumbing (DESIGN.md §13) ----
        events = (EventLog(self.resilience.event_log
                           if self.resilience else None)
                  if (self.resilience or self.chaos) is not None else None)
        manager = (RecoveryManager(self.resilience, events)
                   if self.resilience is not None else None)
        chaos = self.chaos
        if chaos is not None and chaos.events is None:
            chaos.events = events
        train_source = (chaos.wrap_source(self.train_data)
                        if chaos is not None else self.train_data)

        def on_corrupt(s, exc):  # corrupt checkpoint skipped on restore
            if events is not None:
                events.emit("corrupt_checkpoint_skipped", step=s,
                            error=str(exc))

        # ---- resume (fault tolerance: newest valid manifest), restoring
        # the eval trajectory and best-so-far alongside the arrays ----
        state = self.state
        start_step = 0
        resumed_from = None
        eval_history: List[Dict] = []
        best: Optional[Dict] = None
        if ckpt and list_checkpoints(cfg.checkpoint_dir):
            state, manifest = restore(cfg.checkpoint_dir, target=state,
                                      shardings=self.state_shardings,
                                      on_corrupt=on_corrupt)
            start_step = manifest["step"]
            resumed_from = start_step
            eval_history = list(manifest["metadata"].get(
                "eval_history", []))
            best = manifest["metadata"].get("best")

        def make_pipeline(at_step):
            # device staging rides the `put` stage (H2D one step ahead);
            # host transforms (augmentation, chaos) live in the source
            return DataPipeline(
                train_source, start_step=at_step,
                depth=cfg.prefetch_depth,
                num_workers=cfg.data_workers,
                put=self.put_batch,
                device_ahead=cfg.device_ahead)

        prefetch = make_pipeline(start_step)
        history = []
        straggler_events = []
        step_times = []
        data_wait_total = 0.0
        wall_total = 0.0
        last_saved = start_step if resumed_from is not None else -1
        try:
            # anchor checkpoint: rollback must always have a target, even
            # when the divergence hits before the first periodic save
            if manager is not None and ckpt and not list_checkpoints(
                    cfg.checkpoint_dir):
                ckpt.save(start_step, state,
                          metadata=self._ckpt_metadata(eval_history, best))
                last_saved = start_step

            step = start_step
            data_retries_left = (self.resilience.data_retries
                                 if self.resilience else 0)
            while step < total_steps:
                if chaos is not None:
                    chaos.on_step_start(step)
                t0 = time.perf_counter()  # includes data wait: that's what
                try:                      # a straggling host looks like
                    got_step, batch = next(prefetch)
                except Exception as exc:
                    # a dead input worker (the pipeline re-raises from
                    # the consumer). With resilience: bounded pipeline
                    # restarts at the current step; without: propagate
                    # (the pre-existing error contract).
                    if manager is None or data_retries_left <= 0:
                        raise
                    data_retries_left -= 1
                    events.emit("data_restart", step=step,
                                error=str(exc),
                                retries_left=data_retries_left)
                    prefetch.close()
                    prefetch = make_pipeline(step)
                    continue
                data_wait = getattr(prefetch, "last_wait_s", 0.0)
                if got_step != step:
                    # a real error, not an assert: data/step misalignment
                    # silently trains on wrong batches under `python -O`
                    raise RuntimeError(
                        f"prefetcher misalignment: got batch for step "
                        f"{got_step}, expected {step}")
                if self.resilience is not None:
                    data_retries_left = self.resilience.data_retries
                if manager is not None:
                    state, metrics = self.train_step(
                        state, batch, manager.controls(step))
                else:
                    state, metrics = self.train_step(state, batch)
                loss = metrics.get("loss")
                if loss is not None:
                    loss = float(jax.device_get(loss))  # sync point
                dt = time.perf_counter() - t0
                data_wait_total += data_wait
                wall_total += dt
                step_times.append(dt)
                med = float(np.median(step_times[-50:]))
                if len(step_times) > 5 and dt > cfg.deadline_factor * med:
                    straggler_events.append({"step": step, "time": dt,
                                             "median": med})
                    if events is not None:
                        events.emit("straggler", step=step, time=dt,
                                    median=med)

                # ---- recovery decision (before eval/save: a bad step
                # must never be checkpointed or scored) ----
                if manager is not None:
                    host = {"loss": loss}
                    for k in SENTINEL_METRICS + ("grad_norm",):
                        if k in metrics:
                            host[k] = float(jax.device_get(metrics[k]))
                    action = manager.observe(step, host)
                    if action is Action.ABORT:
                        raise RuntimeError(
                            f"training aborted at step {step}: "
                            f"{manager.cfg.max_rollbacks} rollbacks "
                            "exhausted and the step is still diverging "
                            "(see the resilience event log)")
                    if action is Action.ROLLBACK:
                        if ckpt is None:
                            raise RuntimeError(
                                "resilience rollback requires "
                                "TrainerConfig.checkpoint_dir (no "
                                "checkpoint to restore from)")
                        ckpt.wait()  # flush in-flight save + its errors
                        state, manifest = restore(
                            cfg.checkpoint_dir, target=state,
                            shardings=self.state_shardings,
                            on_corrupt=on_corrupt)
                        restored = manifest["step"]
                        eval_history = list(manifest["metadata"].get(
                            "eval_history", []))
                        best = manifest["metadata"].get("best")
                        history = [r for r in history
                                   if r["step"] < restored]
                        prefetch.close()
                        prefetch = make_pipeline(restored)
                        manager.on_rollback(from_step=step,
                                            to_step=restored)
                        last_saved = restored
                        step = restored
                        continue
                    # CONTINUE / SKIPPED fall through: on a skipped step
                    # the state was carried over in-jit, the batch is
                    # simply abandoned

                # mid-streak, hold back eval and checkpoints: the state
                # is identical to the pre-streak state, and saving here
                # would advance the rollback target past the steps that
                # need replaying
                in_bad_streak = (manager is not None
                                 and manager.consecutive_bad > 0)

                if step % cfg.log_every == 0 or step == total_steps - 1:
                    history.append({"step": step, "loss": loss,
                                    "time": dt, "data_wait": data_wait})

                done = step + 1
                # ---- epoch boundary: the paper's eval path ----
                if self._eval_enabled() and not in_bad_streak \
                        and done % cfg.steps_per_epoch == 0:
                    epoch = done // cfg.steps_per_epoch
                    if (epoch % cfg.eval_every_epochs == 0
                            or epoch == cfg.epochs):
                        rec = self.evaluate(state, epoch, done)
                        eval_history.append(rec)
                        top1 = rec.get("top1")
                        if top1 is not None and (
                                best is None or top1 > best["top1"]):
                            best = {"top1": top1, "epoch": epoch,
                                    "step": done}
                            if best_ckpt:
                                best_ckpt.save(
                                    done, state,
                                    metadata=self._ckpt_metadata(
                                        eval_history, best))
                # eval before checkpoint so a resume replays from a
                # manifest that already contains this epoch's record
                if ckpt and cfg.checkpoint_every and not in_bad_streak \
                        and done % cfg.checkpoint_every == 0:
                    ckpt.save(done, state,
                              metadata=self._ckpt_metadata(eval_history,
                                                           best))
                    last_saved = done
                    if chaos is not None \
                            and chaos.has_pending_ckpt_fault(done):
                        ckpt.wait()  # land the save, then corrupt it
                        chaos.after_save(cfg.checkpoint_dir, done)
                step = done
            # final checkpoint — skipped when the periodic save above
            # already wrote this exact step (previously the same step was
            # saved async then immediately re-saved blocking, rmtree-ing
            # the fresh directory)
            if ckpt and last_saved != total_steps:
                ckpt.save(total_steps, state,
                          metadata=self._ckpt_metadata(eval_history, best),
                          block=True)
        finally:
            prefetch.close()
            if best_ckpt:
                best_ckpt.wait()
            if ckpt:
                ckpt.wait()
            if events is not None:
                events.close()
        input_stats = {
            "wall_s": wall_total,
            "data_wait_s": data_wait_total,
            "data_starved_frac": (data_wait_total / wall_total
                                  if wall_total > 0 else 0.0),
        }
        return TrainResult(state=state, history=history,
                           epoch_history=eval_history,
                           straggler_events=straggler_events,
                           resumed_from=resumed_from, best=best,
                           events=list(events.records) if events else [],
                           input_stats=input_stats)


# ---------------------------------------------------------------------------
# Legacy step-driven API (pre-epoch callers: examples, elastic tests)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    log_every: int = 10
    deadline_factor: float = 3.0
    data_workers: int = 1


@dataclasses.dataclass
class LoopResult:
    state: PyTree
    history: list
    straggler_events: list
    resumed_from: Optional[int]


def run_training(
    train_step: Callable,  # jitted (state, batch) -> (state, metrics)
    state: PyTree,
    data,  # has batch_at(step)
    loop_cfg: LoopConfig,
    put_batch: Optional[Callable] = None,  # host batch -> device arrays
    metadata: Optional[Dict] = None,
    state_shardings: Optional[PyTree] = None,
) -> LoopResult:
    """Step-counter training without validation: one ``Trainer`` epoch."""
    cfg = TrainerConfig(
        epochs=1, steps_per_epoch=loop_cfg.total_steps,
        eval_every_epochs=0, val_batches=0,
        checkpoint_every=loop_cfg.checkpoint_every,
        checkpoint_dir=loop_cfg.checkpoint_dir,
        keep_checkpoints=loop_cfg.keep_checkpoints,
        log_every=loop_cfg.log_every,
        deadline_factor=loop_cfg.deadline_factor,
        data_workers=loop_cfg.data_workers)
    result = Trainer(train_step, state, data, cfg, put_batch=put_batch,
                     metadata=metadata,
                     state_shardings=state_shardings).run()
    return LoopResult(state=result.state, history=result.history,
                      straggler_events=result.straggler_events,
                      resumed_from=result.resumed_from)
