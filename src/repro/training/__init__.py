from repro.training.loop import (  # noqa: F401
    LoopConfig,
    LoopResult,
    Trainer,
    TrainerConfig,
    TrainResult,
    run_training,
)
from repro.training.specs import cache_specs, input_specs, param_specs  # noqa: F401
from repro.training.step import (  # noqa: F401
    make_decode_step,
    make_dp_shardmap_train_step,
    make_eval_step,
    make_prefill_step,
    make_train_step,
)
